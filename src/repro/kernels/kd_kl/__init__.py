from repro.kernels.kd_kl.ops import kd_kl_loss  # noqa: F401
from repro.kernels.kd_kl import ref  # noqa: F401
