"""Pure-jnp oracle for the fused KD-KL kernel.

KL(p_T ‖ p_S) per row, with temperature: this is FedGKD's Eq.(3) inner term.
The naive version materializes BOTH (T, V) probability tensors — exactly the
HBM traffic the Pallas kernel eliminates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_kl_rowwise(teacher_logits: jax.Array, student_logits: jax.Array,
                  temperature: float = 1.0) -> jax.Array:
    """(T, V), (T, V) -> (T,) per-row KL(p_T ‖ p_S)·T²."""
    t = temperature
    lt = teacher_logits.astype(jnp.float32) / t
    ls = student_logits.astype(jnp.float32) / t
    p_t = jax.nn.softmax(lt, axis=-1)
    return jnp.sum(p_t * (jax.nn.log_softmax(lt, -1)
                          - jax.nn.log_softmax(ls, -1)), axis=-1) * (t * t)


def kd_kl_grad_student(teacher_logits: jax.Array, student_logits: jax.Array,
                       temperature: float = 1.0) -> jax.Array:
    """d KL / d student_logits = (p_S − p_T)·T (per row)."""
    t = temperature
    p_t = jax.nn.softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    p_s = jax.nn.softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    return (p_s - p_t) * t
