"""Public jit'd wrapper for the fused KD-KL loss with custom VJP.

``kd_kl_loss(teacher_logits, student_logits)`` accepts any (..., V) shapes,
flattens leading dims, pads rows/vocab to block multiples (padded vocab
columns are −inf'd so they contribute nothing), and returns per-row KL with
gradients flowing ONLY to the student (teacher is a frozen ensemble in
FedGKD, Eq. 4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kd_kl import kernel as K
from repro.kernels.kd_kl import ref

_PAD = -1e30


def _pad2(x, br, bv, fill):
    t, v = x.shape
    pt, pv = (-t) % br, (-v) % bv
    if pt or pv:
        x = jnp.pad(x, ((0, pt), (0, pv)), constant_values=fill)
    return x


def _fwd_impl(teacher_logits, student_logits, temperature, block_rows,
              block_vocab, interpret):
    lt = _pad2(teacher_logits, block_rows, block_vocab, _PAD)
    ls = _pad2(student_logits, block_rows, block_vocab, _PAD)
    kl, lse_t, lse_s = K.kd_kl_fwd(
        lt, ls, temperature=temperature, block_rows=block_rows,
        block_vocab=block_vocab, interpret=interpret)
    return kl[: teacher_logits.shape[0]], lse_t, lse_s


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _kd_kl_rows(teacher_logits, student_logits, temperature, block_rows,
                block_vocab, interpret):
    return _fwd_impl(teacher_logits, student_logits, temperature, block_rows,
                     block_vocab, interpret)[0]


def _fwd(teacher_logits, student_logits, temperature, block_rows,
         block_vocab, interpret):
    # the (padded-length) row logsumexps fall out of the forward kernel's
    # online-softmax scratch — saving them as residuals lets the backward
    # rebuild p_T/p_S without re-reducing the vocab axis
    out, lse_t, lse_s = _fwd_impl(teacher_logits, student_logits, temperature,
                                  block_rows, block_vocab, interpret)
    return out, (teacher_logits, student_logits, lse_t, lse_s)


def _bwd(temperature, block_rows, block_vocab, interpret, res, g):
    lt, ls, lse_t, lse_s = res
    t, v = lt.shape
    ltp = _pad2(lt, block_rows, block_vocab, _PAD)
    lsp = _pad2(ls, block_rows, block_vocab, _PAD)
    gp = jnp.pad(g, (0, (-t) % block_rows))
    dls = K.kd_kl_bwd(ltp, lsp, lse_t, lse_s, gp.astype(jnp.float32),
                      temperature=temperature, block_rows=block_rows,
                      block_vocab=block_vocab, interpret=interpret)
    # temperature² from fwd's /(inv²) cancels one 1/temp of d(l/temp): net ·temp
    dls = dls[:t, :v] * temperature * temperature
    return jnp.zeros_like(lt), dls.astype(ls.dtype)


_kd_kl_rows.defvjp(_fwd, _bwd)


def kd_kl_loss(teacher_logits: jax.Array, student_logits: jax.Array, *,
               temperature: float = 1.0, block_rows: int = 256,
               block_vocab: int = 1024, interpret: bool | None = None,
               use_pallas: bool = True) -> jax.Array:
    """Per-example KL(p_T‖p_S)·temp² over the last axis; leading dims kept.

    ``use_pallas=False`` falls back to the jnp oracle (CPU training path).
    ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    shape = teacher_logits.shape
    assert shape == student_logits.shape
    if not use_pallas:
        # stop_gradient keeps the fallback's VJP identical to the kernel's
        # custom VJP (teacher gradient is zero on BOTH backends)
        return ref.kd_kl_rowwise(jax.lax.stop_gradient(teacher_logits),
                                 student_logits, temperature)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lt = teacher_logits.reshape(-1, shape[-1])
    ls = student_logits.reshape(-1, shape[-1])
    out = _kd_kl_rows(lt, ls, temperature, block_rows, block_vocab, interpret)
    return out.reshape(shape[:-1])
