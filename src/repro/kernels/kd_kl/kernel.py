"""Fused softmax+KL distillation loss — Pallas TPU kernel.

One pass over the vocab axis computes, per row, the online-rescaled
accumulators of BOTH softmaxes and the cross term:

    m_t, s_t : running max / rescaled exp-sum of teacher logits
    m_s, s_s : same for student
    acc      : Σ exp(lt − m_t)·(lt − ls)   (rescaled as m_t moves)

    KL = acc/s_t − (m_t + log s_t) + (m_s + log s_s)

so neither probability tensor ever hits HBM: traffic is exactly one read of
each logits tensor (2·T·V·4B) instead of the reference's reads+writes of two
prob tensors (≥6·T·V·4B), and the row reduction lives in VMEM scratch.

Grid: (row_blocks, vocab_blocks); the vocab axis is the innermost (sequen-
tially iterated on TPU) so scratch carries across vocab blocks.  Block
shapes default to (256 rows, 1024 vocab) — 2·1MB fp32 blocks in VMEM, MXU/
VPU-aligned (multiples of 8×128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kd_kl_fwd_kernel(lt_ref, ls_ref, out_ref, lse_t_ref, lse_s_ref,
                      mt_ref, st_ref, ms_ref, ss_ref, acc_ref,
                      *, inv_temp: float, n_vblocks: int):
    """One (row_block, vocab_block) step. Scratch refs carry row stats."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG_INF)
        st_ref[...] = jnp.zeros_like(st_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ss_ref[...] = jnp.zeros_like(ss_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lt = lt_ref[...].astype(jnp.float32) * inv_temp      # (R, Vb)
    ls = ls_ref[...].astype(jnp.float32) * inv_temp

    # teacher online softmax + cross accumulator
    mt_prev, st_prev, acc_prev = mt_ref[...], st_ref[...], acc_ref[...]
    mt_new = jnp.maximum(mt_prev, jnp.max(lt, axis=-1))
    scale_t = jnp.exp(mt_prev - mt_new)
    e_t = jnp.exp(lt - mt_new[:, None])
    st_ref[...] = st_prev * scale_t + jnp.sum(e_t, axis=-1)
    acc_ref[...] = acc_prev * scale_t + jnp.sum(e_t * (lt - ls), axis=-1)
    mt_ref[...] = mt_new

    # student online logsumexp
    ms_prev, ss_prev = ms_ref[...], ss_ref[...]
    ms_new = jnp.maximum(ms_prev, jnp.max(ls, axis=-1))
    ss_ref[...] = ss_prev * jnp.exp(ms_prev - ms_new) + jnp.sum(
        jnp.exp(ls - ms_new[:, None]), axis=-1)
    ms_ref[...] = ms_new

    @pl.when(j == n_vblocks - 1)
    def _finalize():
        lse_t = mt_ref[...] + jnp.log(st_ref[...])
        lse_s = ms_ref[...] + jnp.log(ss_ref[...])
        lse_t_ref[...] = lse_t
        lse_s_ref[...] = lse_s
        out_ref[...] = (acc_ref[...] / st_ref[...] - lse_t + lse_s) / (inv_temp * inv_temp)


def kd_kl_fwd(teacher_logits: jax.Array, student_logits: jax.Array, *,
              temperature: float = 1.0, block_rows: int = 256,
              block_vocab: int = 1024, interpret: bool = False):
    """(T, V) × (T, V) -> (kl (T,), lse_t (T,), lse_s (T,)).  T % block_rows
    == 0 and V % block_vocab == 0 (ops.py pads).

    The row logsumexps fall out of the online-softmax scratch for free; the
    custom VJP saves them as residuals so the backward pass rebuilds both
    probability rows without re-reducing the vocab axis (saves two full
    reads of the logits tensors per backward)."""
    t, v = teacher_logits.shape
    assert t % block_rows == 0 and v % block_vocab == 0, (t, v)
    n_rblocks, n_vblocks = t // block_rows, v // block_vocab

    kernel = functools.partial(_kd_kl_fwd_kernel, inv_temp=1.0 / temperature,
                               n_vblocks=n_vblocks)
    row_spec = pl.BlockSpec((block_rows,), lambda i, j: (i,))
    row_shape = jax.ShapeDtypeStruct((t,), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(n_rblocks, n_vblocks),
        in_specs=[
            pl.BlockSpec((block_rows, block_vocab), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_vocab), lambda i, j: (i, j)),
        ],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[row_shape, row_shape, row_shape],
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),  # m_t
            pltpu.VMEM((block_rows,), jnp.float32),  # s_t
            pltpu.VMEM((block_rows,), jnp.float32),  # m_s
            pltpu.VMEM((block_rows,), jnp.float32),  # s_s
            pltpu.VMEM((block_rows,), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(teacher_logits, student_logits)


def _kd_kl_bwd_kernel(lt_ref, ls_ref, lse_t_ref, lse_s_ref, g_ref, dls_ref,
                      *, inv_temp: float):
    """Block-wise student gradient: g_row · (p_S − p_T) · inv_temp...

    Using saved row logsumexps: p = exp(l·inv_temp − lse)."""
    lt = lt_ref[...].astype(jnp.float32) * inv_temp
    ls = ls_ref[...].astype(jnp.float32) * inv_temp
    p_t = jnp.exp(lt - lse_t_ref[...][:, None])
    p_s = jnp.exp(ls - lse_s_ref[...][:, None])
    dls_ref[...] = (g_ref[...][:, None] * (p_s - p_t) * inv_temp).astype(dls_ref.dtype)


def kd_kl_bwd(teacher_logits, student_logits, lse_t, lse_s, g, *,
              temperature: float = 1.0, block_rows: int = 256,
              block_vocab: int = 1024, interpret: bool = False) -> jax.Array:
    """Gradient wrt student logits, given saved row logsumexps."""
    t, v = teacher_logits.shape
    n_rblocks, n_vblocks = t // block_rows, v // block_vocab
    kernel = functools.partial(_kd_kl_bwd_kernel, inv_temp=1.0 / temperature)
    return pl.pallas_call(
        kernel,
        grid=(n_rblocks, n_vblocks),
        in_specs=[
            pl.BlockSpec((block_rows, block_vocab), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_vocab), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows,), lambda i, j: (i,)),
            pl.BlockSpec((block_rows,), lambda i, j: (i,)),
            pl.BlockSpec((block_rows,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_vocab), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, v), student_logits.dtype),
        interpret=interpret,
    )(teacher_logits, student_logits, lse_t, lse_s, g)


def _row_lse_kernel(l_ref, out_ref, m_ref, s_ref, *, inv_temp, n_vblocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)

    x = l_ref[...].astype(jnp.float32) * inv_temp
    m_prev, s_prev = m_ref[...], s_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    s_ref[...] = s_prev * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new[:, None]), axis=-1)
    m_ref[...] = m_new

    @pl.when(j == n_vblocks - 1)
    def _fin():
        out_ref[...] = m_ref[...] + jnp.log(s_ref[...])


def row_logsumexp(logits: jax.Array, *, temperature: float = 1.0,
                  block_rows: int = 256, block_vocab: int = 1024,
                  interpret: bool = False) -> jax.Array:
    """(T, V) -> (T,) logsumexp(l/temp).

    Standalone utility; the KD-KL backward no longer calls it — the forward
    kernel now emits both row logsumexps as VJP residuals."""
    t, v = logits.shape
    n_rblocks, n_vblocks = t // block_rows, v // block_vocab
    kernel = functools.partial(_row_lse_kernel, inv_temp=1.0 / temperature,
                               n_vblocks=n_vblocks)
    return pl.pallas_call(
        kernel,
        grid=(n_rblocks, n_vblocks),
        in_specs=[pl.BlockSpec((block_rows, block_vocab), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_rows,), jnp.float32),
                        pltpu.VMEM((block_rows,), jnp.float32)],
        interpret=interpret,
    )(logits)
