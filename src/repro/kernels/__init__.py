"""Pallas TPU kernels for the perf-critical compute paths.

    kd_kl           fused softmax+KL distillation loss (FedGKD's added compute)
    flash_attention blockwise causal/sliding-window attention
    ssd_scan        Mamba2 chunked state-space scan

Each kernel package has kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper, custom_vjp), ref.py (pure-jnp oracle).  Kernels are
written for TPU (VMEM tiling, MXU-aligned blocks) and validated on CPU with
interpret=True.
"""
