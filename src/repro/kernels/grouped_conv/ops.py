"""Public client-batched convolution with custom VJP.

``client_batched_conv(x, w, stride=, padding=)`` convolves K clients'
batches with K different filter stacks in ONE program:

    x (K, N, H, W, Cin) ⊛ w (K, kh, kw, Cin, Cout) -> (K, N, OH, OW, Cout)

Forward: the Pallas im2col-blocked-matmul kernel on TPU, the pure-JAX
grouped-conv oracle (``ref.grouped_pack_conv``) elsewhere — same selection
convention as ``kernels.kd_kl.ops`` (``use_pallas=None`` auto-detects,
``interpret=None`` auto-selects interpret mode off-TPU).

Backward: a custom VJP, because autodiff of EITHER forward is wrong-shaped
for speed — XLA expresses the rhs-gradient of a feature-grouped conv as a
``batch_group_count`` convolution that is pathologically slow on CPU
(measured ~65x on the resnet8 shapes), and the Pallas forward has no
registered gradient at all.  The VJP formulas stay block-diagonal over
clients:

    dx = feature-grouped transposed conv   (``ref.grouped_conv_dx``)
    dw = kh*kw client-batched GEMMs        (``ref.shift_gemm_dw``)

both measured at-or-better than the vmapped per-client gradients on the
CPU dev box (see ROADMAP for the per-layer table).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.grouped_conv import kernel as K
from repro.kernels.grouped_conv import ref

_LANES = 128    # TPU lane width: channel axes are padded to this for the MXU


def _pad_axis(a: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pallas_fwd(x, w, stride, padding, interpret):
    k, n, h, wd, cin = x.shape
    kh, kw, cout = w.shape[1], w.shape[2], w.shape[4]
    oh, lo_h, hi_h = ref.resolve_pads(h, kh, stride, padding)
    ow, lo_w, hi_w = ref.resolve_pads(wd, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), (0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    xp = _pad_axis(xp, 4, _LANES)
    wp = _pad_axis(_pad_axis(w, 3, _LANES), 4, _LANES)
    out = K.grouped_conv_fwd(xp, wp, stride=stride, oh=oh, ow=ow,
                             interpret=interpret)
    return out[..., :cout]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _conv(x, w, stride, padding, use_pallas, interpret):
    if use_pallas:
        return _pallas_fwd(x, w, stride, padding, interpret)
    return ref.grouped_pack_conv(x, w, stride, padding)


def _conv_fwd(x, w, stride, padding, use_pallas, interpret):
    return _conv(x, w, stride, padding, use_pallas, interpret), (x, w)


def _conv_bwd(stride, padding, use_pallas, interpret, res, dy):
    x, w = res
    dx = ref.grouped_conv_dx(dy, w, stride, x.shape[2], x.shape[3], padding)
    dw = ref.shift_gemm_dw(x, dy, stride, w.shape[1], w.shape[2], padding)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_conv.defvjp(_conv_fwd, _conv_bwd)


def client_batched_conv(x: jax.Array, w: jax.Array, *, stride: int = 1,
                        padding: str = "SAME",
                        use_pallas: bool | None = None,
                        interpret: bool | None = None) -> jax.Array:
    """Per-client convolution over a stacked cohort, one fused program.

    ``use_pallas=None`` selects the Pallas kernel on TPU and the grouped
    jnp oracle elsewhere; ``interpret=None`` auto-enables interpret mode
    off-TPU (tests force ``use_pallas=True, interpret=True`` on CPU).
    Gradients flow to both ``x`` and ``w`` through the custom VJP
    regardless of the forward backend.
    """
    if x.ndim != 5 or w.ndim != 5:
        raise ValueError(
            f"client_batched_conv wants x (K, N, H, W, Cin) and w "
            f"(K, kh, kw, Cin, Cout); got {x.shape} and {w.shape}")
    if x.shape[0] != w.shape[0]:
        raise ValueError(
            f"client axes disagree: x has K={x.shape[0]}, w has "
            f"K={w.shape[0]}")
    if padding not in ("SAME", "VALID"):
        raise ValueError(
            f"padding must be 'SAME' or 'VALID', got {padding!r}")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _conv(x, w, int(stride), padding, bool(use_pallas),
                 bool(interpret))
