"""Client-batched convolution forward — Pallas TPU kernel.

One grid step computes one (client, example) output plane as an im2col
blocked matmul: the kh*kw filter taps are accumulated as

    acc (OH*OW, Cin) @ w[k, i, j] (Cin, Cout)

on the MXU, with the shifted input patch sliced from the (pre-padded) VMEM
block — the patch matrix is never materialized in HBM (implicit im2col).
Grid: ``(K, N)`` — each client's weights are loaded once per example block
and every client convolves with ITS OWN filters, which is exactly the
computation the batched executors need and the thing a vmapped
``conv_general_dilated`` lowers badly.

Layout notes (see the Pallas guide's tiling constraints): channel axes are
padded to 128 lanes by ``ops.py`` before the call, so the dot shapes are
lane-aligned; spatial padding (SAME) also happens outside — the kernel
always computes a VALID conv over the padded block.  Strided taps use a
strided ``lax.slice``; validated in interpret mode (CI runs every kernel
test there), real-TPU Mosaic validation is a listed follow-up since this
tree has no TPU attached.

The backward runs through the pure-JAX formulas in ``ref.py`` (grouped
transposed conv for dx, shift-GEMM for dw) via the custom VJP in
``ops.py``; a fused backward kernel is a follow-up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, w_ref, out_ref, *, stride: int, kh: int, kw: int,
                oh: int, ow: int):
    """One (client, example): VALID conv of the padded plane with one
    client's filters, accumulated tap by tap on the MXU."""
    xv = x_ref[0, 0]                                   # (Hp, Wp, Cin)
    cin = xv.shape[-1]
    cout = w_ref.shape[-1]
    acc = jnp.zeros((oh * ow, cout), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xv, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, cin),
                (stride, stride, 1))                   # (OH, OW, Cin)
            acc = acc + jnp.dot(patch.reshape(oh * ow, cin), w_ref[0, i, j],
                                preferred_element_type=jnp.float32)
    out_ref[0, 0] = acc.reshape(oh, ow, cout).astype(out_ref.dtype)


def grouped_conv_fwd(x_padded: jax.Array, w: jax.Array, *, stride: int,
                     oh: int, ow: int, interpret: bool = False) -> jax.Array:
    """(K, N, Hp, Wp, Cin) ⊛ (K, kh, kw, Cin, Cout) -> (K, N, OH, OW, Cout).

    ``x_padded`` already carries the SAME/VALID spatial padding; channel
    axes should be lane-padded by the caller (``ops.py`` does both).
    """
    k, n, hp, wp, cin = x_padded.shape
    kh, kw, cout = w.shape[1], w.shape[2], w.shape[4]
    kernel = functools.partial(_fwd_kernel, stride=stride, kh=kh, kw=kw,
                               oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(k, n),
        in_specs=[
            pl.BlockSpec((1, 1, hp, wp, cin), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, kh, kw, cin, cout),
                         lambda i, j: (i, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, oh, ow, cout),
                               lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n, oh, ow, cout), x_padded.dtype),
        interpret=interpret,
    )(x_padded, w)
