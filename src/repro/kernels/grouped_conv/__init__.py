from repro.kernels.grouped_conv.ops import client_batched_conv  # noqa: F401
from repro.kernels.grouped_conv.ref import (  # noqa: F401
    grouped_pack_conv, naive_vmap_conv,
)
