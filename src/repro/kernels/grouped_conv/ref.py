"""Pure-jnp oracles for the client-batched convolution.

The problem: a cohort of K clients holds K *different* conv weights, and the
batched executors want one program that convolves every client's batch with
its own kernel,

    x (K, N, H, W, Cin) ⊛ w (K, kh, kw, Cin, Cout) -> (K, N, OH, OW, Cout).

``naive_vmap_conv`` is what ``jax.vmap`` over clients produces today — a
batched-weight convolution XLA lowers poorly on CPU (and that the executor
benchmarks use as the baseline).  ``grouped_pack_conv`` rewrites it as ONE
``lax.conv_general_dilated`` with ``feature_group_count=K``: the K client
channel blocks are packed side by side (block-diagonal in channel space), so
group g of the big conv sees exactly client g's channels and client g's
filters.  Forward cost is identical FLOPs with none of the batching-rule
overhead.

Only the FORWARD rewrite lives here.  Differentiating ``grouped_pack_conv``
directly is a trap: XLA expresses the rhs-gradient of a feature-grouped conv
as a ``batch_group_count`` convolution, which is catastrophically slow on
CPU (measured ~65x slower than the formulas in ``ops.py``) — which is why
``ops.client_batched_conv`` wraps this oracle in a custom VJP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DIMS = ("NHWC", "HWIO", "NHWC")


def conv_ref(x: jax.Array, w: jax.Array, stride: int = 1,
             padding: str = "SAME") -> jax.Array:
    """Single-client reference: (N, H, W, Cin) ⊛ (kh, kw, Cin, Cout)."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=DIMS)


def naive_vmap_conv(x: jax.Array, w: jax.Array, stride: int = 1,
                    padding: str = "SAME") -> jax.Array:
    """The executor's historical path: vmap the per-client conv over K.

    Lowers to batched-weight convolutions (the ROADMAP's "vmap over
    per-client conv weights lowers poorly" item); kept as the benchmark
    baseline and the semantics oracle for tests.
    """
    return jax.vmap(lambda x1, w1: conv_ref(x1, w1, stride, padding))(x, w)


def same_pads(size: int, k: int, stride: int) -> tuple[int, int, int]:
    """(out_size, pad_lo, pad_hi) of a SAME conv along one spatial axis."""
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    lo = pad // 2
    return out, lo, pad - lo


def valid_pads(size: int, k: int, stride: int) -> tuple[int, int, int]:
    return (size - k) // stride + 1, 0, 0


def resolve_pads(size: int, k: int, stride: int, padding: str):
    if padding == "SAME":
        return same_pads(size, k, stride)
    if padding == "VALID":
        return valid_pads(size, k, stride)
    raise ValueError(f"padding must be 'SAME' or 'VALID', got {padding!r}")


def grouped_pack_conv(x: jax.Array, w: jax.Array, stride: int = 1,
                      padding: str = "SAME") -> jax.Array:
    """The K-vmapped conv as ONE feature-grouped convolution.

    Channel packing: x (K, N, H, W, Cin) -> (N, H, W, K*Cin) with client k's
    channels occupying block k; w -> (kh, kw, Cin, K*Cout) with client k's
    filters producing output block k.  ``feature_group_count=K`` makes the
    big conv block-diagonal over clients — no cross-client mixing.
    """
    k, n, h, wd, cin = x.shape
    kh, kw, cout = w.shape[1], w.shape[2], w.shape[4]
    xg = jnp.transpose(x, (1, 2, 3, 0, 4)).reshape(n, h, wd, k * cin)
    wg = jnp.transpose(w, (1, 2, 3, 0, 4)).reshape(kh, kw, cin, k * cout)
    out = jax.lax.conv_general_dilated(
        xg, wg, (stride, stride), padding, dimension_numbers=DIMS,
        feature_group_count=k)
    oh, ow = out.shape[1], out.shape[2]
    return out.reshape(n, oh, ow, k, cout).transpose(3, 0, 1, 2, 4)


def grouped_conv_dx(dy: jax.Array, w: jax.Array, stride: int, h: int,
                    wd: int, padding: str = "SAME") -> jax.Array:
    """Input gradient as ONE feature-grouped transposed convolution.

    dx = conv(dy dilated by the stride, w rotated 180° with Cin/Cout
    swapped), still block-diagonal over clients.  Crucially this is a
    *feature*-grouped conv again (the lhs-transpose of a feature-grouped
    conv stays feature-grouped), so it avoids the batch-grouped lowering
    that makes autodiff of ``grouped_pack_conv`` pathological on CPU.
    """
    k, n, oh, ow, cout = dy.shape
    kh, kw, cin = w.shape[1], w.shape[2], w.shape[3]
    _, lo_h, _ = resolve_pads(h, kh, stride, padding)
    _, lo_w, _ = resolve_pads(wd, kw, stride, padding)
    wr = jnp.flip(w, axis=(1, 2)).transpose(0, 1, 2, 4, 3)
    dyg = jnp.transpose(dy, (1, 2, 3, 0, 4)).reshape(n, oh, ow, k * cout)
    wg = jnp.transpose(wr, (1, 2, 3, 0, 4)).reshape(kh, kw, cout, k * cin)
    out = jax.lax.conv_general_dilated(
        dyg, wg, (1, 1),
        [(kh - 1 - lo_h, h - ((oh - 1) * stride + 1) + lo_h),
         (kw - 1 - lo_w, wd - ((ow - 1) * stride + 1) + lo_w)],
        lhs_dilation=(stride, stride), dimension_numbers=DIMS,
        feature_group_count=k)
    return out.reshape(n, h, wd, k, cin).transpose(3, 0, 1, 2, 4)


def shift_gemm_dw(x: jax.Array, dy: jax.Array, stride: int,
                  kh: int, kw: int, padding: str = "SAME") -> jax.Array:
    """Weight gradient as kh*kw K-batched GEMMs (implicit im2col).

    dw[k, i, j] = x_shifted(i, j)ᵀ · dy — each (i, j) tap is one
    ``dot_general`` with the client axis as the GEMM batch dimension, which
    CPUs and TPUs both lower as clean batched matmuls.  This replaces the
    ``batch_group_count`` convolution XLA would emit for the rhs-gradient
    (measured up to ~10x faster on strided and 1x1 layers, ~parity on
    stride-1 3x3 — see ROADMAP).
    """
    k, n, h, wd, cin = x.shape
    oh, ow, cout = dy.shape[2], dy.shape[3], dy.shape[4]
    _, lo_h, hi_h = resolve_pads(h, kh, stride, padding)
    _, lo_w, hi_w = resolve_pads(wd, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), (0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    dyf = dy.reshape(k, n * oh * ow, cout)
    taps = []
    for i in range(kh):
        for j in range(kw):
            xs = jax.lax.slice(
                xp, (0, 0, i, j, 0),
                (k, n, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1,
                 cin),
                (1, 1, stride, stride, 1)).reshape(k, n * oh * ow, cin)
            taps.append(jax.lax.dot_general(
                xs, dyf, (((1,), (1,)), ((0,), (0,)))))
    return jnp.stack(taps, 1).reshape(k, kh, kw, cin, cout)
