from repro.kernels.ssd_scan.ops import ssd_scan  # noqa: F401
from repro.kernels.ssd_scan import ref  # noqa: F401
