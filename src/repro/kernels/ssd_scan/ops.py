"""Jit'd wrapper for the SSD scan kernel: (b,l,h,p) layout + custom VJP.

Backward differentiates the chunked jnp oracle (identical math) via
``jax.vjp`` — the fwd kernel is the prefill/train hot path; a fused bwd
kernel is a listed follow-up in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as K
from repro.models.ssm import ssd_chunked


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, a, b, c, chunk, interpret):
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, l, p).astype(jnp.float32)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, l).astype(jnp.float32)
    af = jnp.tile(a.astype(jnp.float32), bsz).reshape(bsz * h, 1)
    bf = jnp.repeat(b, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * h, l, n).astype(jnp.float32)
    cf = jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * h, l, n).astype(jnp.float32)
    pad = (-l) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad)))
        bf = jnp.pad(bf, ((0, 0), (0, pad), (0, 0)))
        cf = jnp.pad(cf, ((0, 0), (0, pad), (0, 0)))
    y, state = K.ssd_scan_fwd(xf, dtf, af, bf, cf, chunk=chunk,
                              interpret=interpret)
    y = y[:, :l].reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
    state = state.reshape(bsz, h, p, n)
    return y.astype(x.dtype), state


def _fwd(x, dt, a, b, c, chunk, interpret):
    out = _ssd(x, dt, a, b, c, chunk, interpret)
    return out, (x, dt, a, b, c)


def _bwd(chunk, interpret, res, g):
    x, dt, a, b, c = res
    l = x.shape[1]
    pad = (-l) % chunk
    gy, gstate = g

    def f(x, dt, a, b, c):
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, state = ssd_chunked(
            x.astype(jnp.float32), dt.astype(jnp.float32), a,
            b.astype(jnp.float32), c.astype(jnp.float32),
            chunk=min(chunk, x.shape[1]))
        return y[:, :l], state

    _, vjp = jax.vjp(f, x, dt, a, b, c)
    return vjp((gy, gstate))


_ssd.defvjp(_fwd, _bwd)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Pallas SSD scan; same contract as models.ssm.ssd_chunked.

    x: (B, L, H, P); dt: (B, L, H) (softplus'ed); a: (H,) negative;
    b/c: (B, L, G, N).  Returns (y, final_state (B, H, P, N)).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _ssd(x, dt, a, b, c, chunk, interpret)
