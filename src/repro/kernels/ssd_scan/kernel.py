"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

State-space duality structure per (batch·head, chunk):
  intra-chunk   y_diag = (C·Bᵀ ∘ decay-mask) · (dt ∘ x)     — MXU matmuls
  state carry   S ← S·exp(Σ dA) + Bᵀ·(dt·exp(tail-decay)·x) — (P, N) in VMEM
  inter-chunk   y_off  = C·Sᵀ ∘ exp(cum-decay)

The chunk axis is the innermost (sequential) grid dimension; the (P, N)
state lives in VMEM scratch across chunk iterations — the TPU analogue of
the paper-algorithm's SRAM-resident inter-chunk recurrence on GPU.

Block shapes: chunk Q=128 rows × (P=64, N=128) — all operands ≤ 64 KB fp32;
matmul dims (Q×N)·(N×Q), (Q×Q)·(Q×P) are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q,)
    a = a_ref[0, 0]                          # scalar decay rate (negative)
    bm = b_ref[0].astype(jnp.float32)       # (Q, N)
    cm = c_ref[0].astype(jnp.float32)       # (Q, N)

    da = dt * a                              # (Q,) negative increments
    cums = jnp.cumsum(da)                    # within-chunk cumulative decay

    # ---- intra-chunk (dual attention form)
    seg = cums[:, None] - cums[None, :]      # (Q, Q): Σ_{j<k<=i} da_k
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    g = (cm @ bm.T) * decay                  # (Q, Q)
    y = (g * dt[None, :]) @ x                # (Q, P)

    # ---- contribution of the incoming state
    state = state_ref[...]                   # (P, N)
    y = y + (cm @ state.T) * jnp.exp(cums)[:, None]

    # ---- state update for the next chunk
    tail = jnp.exp(cums[-1] - cums)          # (Q,)
    state_ref[...] = state * jnp.exp(cums[-1]) + \
        ((dt * tail)[:, None] * x).T @ bm    # (P, N)

    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, ...] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan_fwd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, *, chunk: int = 128,
                 interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (BH, L, P); dt: (BH, L); a: (BH, 1); b/c: (BH, L, N).
    Returns (y (BH, L, P), final_state (BH, P, N)).  L % chunk == 0."""
    bh, l, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
