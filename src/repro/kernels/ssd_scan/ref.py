"""Oracles for the SSD scan kernel: the O(L) sequential recurrence and the
chunked dual form (both in repro.models.ssm, re-exported here so the kernel
package is self-contained per the kernels/<name>+ops+ref convention)."""
from repro.models.ssm import ssd_reference, ssd_chunked  # noqa: F401
