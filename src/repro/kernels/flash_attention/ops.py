"""Jit'd public wrapper: GQA layout handling + padding + custom VJP.

Forward runs the Pallas kernel; backward recomputes attention with the jnp
reference under ``jax.vjp`` (flash-bwd kernel is a possible follow-up — the
fwd kernel is what the prefill roofline needs; noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _pad_seq(x, block, axis):
    pad = (-x.shape[axis]) % block
    if pad:
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        x = jnp.pad(x, cfg)
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, block_q, block_kv, interpret):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    # layout: fold heads into batch; repeat kv heads per group
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1).reshape(b * hq, skv, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1).reshape(b * hq, skv, d)
    qf = _pad_seq(qf, block_q, 1)
    kf = _pad_seq(kf, block_kv, 1)
    vf = _pad_seq(vf, block_kv, 1)
    # padded kv positions must never be attended: they sit at k_pos >= skv;
    # causal masking handles them iff sq <= skv. For the non-causal case we
    # mask via window=None + explicit slice below only when no padding.
    out = K.flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                                block_q=block_q, block_kv=block_kv,
                                interpret=interpret)
    out = out[:, :sq].reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
    return out


def _flash_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    return _flash(q, k, v, causal, window, block_q, block_kv, interpret), (q, k, v)


def _flash_bwd(causal, window, block_q, block_kv, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.attention_ref(q, k, v, causal=causal, window=window),
        q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        block_q: int = 128, block_kv: int = 128,
                        interpret: bool | None = None) -> jax.Array:
    """Drop-in for attention.jnp_attention with Pallas execution.

    Non-causal calls with sequence padding would attend padded keys, so those
    fall back to the reference (encoder/cross-attention seqs are short).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    skv = k.shape[1]
    if not causal and skv % block_kv != 0:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal, window, block_q, block_kv, interpret)
