"""Pure-jnp oracle for flash attention (delegates to the model-stack ref)."""
from __future__ import annotations

import jax

from repro.models.attention import dot_product_attention, causal_mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D); GQA via head grouping."""
    mask = causal_mask(q.shape[1], k.shape[1], window=window) if causal else None
    return dot_product_attention(q, k, v, mask)
