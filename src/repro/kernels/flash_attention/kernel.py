"""Blockwise (flash) attention — Pallas TPU kernel, causal + sliding-window.

Online-softmax over KV blocks: for each (batch·head, q_block) the kernel
iterates KV blocks (innermost sequential grid axis) carrying running max
``m``, normalizer ``l`` and the unnormalized output accumulator in VMEM.

Blocks default to 128×128 with the full head_dim resident — q/k/v tiles are
(128, D≤128) ⇒ ≤64 KB each in fp32, comfortably inside the ~16 MB/core VMEM
budget, and 128 matches the MXU systolic dimensions.

Block-level masking: a KV block entirely in the future (causal) or entirely
outside the window contributes nothing; we still visit it but its weights
are −inf-masked — a production TPU build would prune the grid; we keep the
single-grid form for clarity and note the pruning in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_kv: int, n_kv_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, d)
    v = v_ref[0].astype(jnp.float32)

    s = q @ k.T                                          # (bq, bkv)

    q_pos = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = j * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        # rows with no valid key (fully masked) produce l==0; emit zeros
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None, block_q: int = 128,
                        block_kv: int = 128, interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D). Shapes pre-padded to block multiples."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    n_q, n_kv = sq // block_q, skv // block_kv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv_blocks=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
