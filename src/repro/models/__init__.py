"""Model substrate: layers, attention, MoE, SSM, hybrid, enc-dec, ResNet.

Everything is pure JAX (no flax): a model is a pair of functions
``init(rng, cfg) -> params`` and ``apply(params, cfg, batch, ...) -> logits``
over plain-dict pytrees, plus decode-path helpers that carry explicit
KV/SSM caches.
"""
from repro.models import layers, attention, moe, ssm, transformer, resnet, frontends  # noqa: F401
