"""Attention: GQA/MHA, MLA (DeepSeek), sliding-window, and KV-cache decode.

Two execution paths:
  * ``dot_product_attention`` — pure-jnp reference used on CPU and as the
    oracle for the Pallas flash kernel.
  * the Pallas flash kernel (repro.kernels.flash_attention) — selected with
    ``cfg.use_pallas`` on TPU targets.

Cache layouts
  GQA : k/v  (batch, max_len, kv_heads, head_dim); SWA uses a ring buffer of
        ``window`` slots indexed modulo window.
  MLA : compressed c_kv (batch, max_len, kv_lora_rank) + rope key
        (batch, max_len, qk_rope_dim) — the memory-saving layout from
        DeepSeek-V2/V3 adapted to a jnp cache.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Params, apply_rope, dense, dense_init

NEG_INF = -2.0 ** 30  # large-negative that is safe in bf16 accumulation


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def causal_mask(q_len: int, kv_len: int, *, window: int | None = None,
                q_offset: int | jax.Array = 0) -> jax.Array:
    """(q_len, kv_len) boolean mask. True = attend.

    ``q_offset`` is the absolute position of query row 0 (for decode /
    chunked prefill).  ``window`` enables sliding-window attention: query at
    absolute position p attends to keys in [p-window+1, p].
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def _constrain(x: jax.Array, spec) -> jax.Array:
    """Best-effort sharding constraint (no-op outside a mesh context)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def dot_product_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          mask: jax.Array | None, *, scale: float | None = None,
                          logits_soft_cap: float | None = None,
                          shard_spec: tuple | None = None) -> jax.Array:
    """q: (B, Sq, Hq, D), k/v: (B, Skv, Hkv, D) with Hq % Hkv == 0 (GQA).

    Returns (B, Sq, Hq, D).  mask: broadcastable to (B, Hq, Sq, Skv) or
    (Sq, Skv).  ``shard_spec=(dp_axis, sp_axis)`` constrains the (B, Hkv, G,
    Sq, Skv) score tensor to batch×sequence-parallel sharding — prevents the
    SPMD partitioner from splitting the head_dim CONTRACTION across the
    model axis (which materializes and all-reduces the full S×S scores; see
    EXPERIMENTS.md §Perf pair C).
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    # accumulate in f32 on the MXU without materializing f32 copies of the
    # (possibly huge) KV cache — crucial for the decode-path memory roofline
    qg = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if shard_spec is not None:
        dp, sp = shard_spec
        logits = _constrain(logits, (dp, None, None, sp, None))
    if logits_soft_cap is not None:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)
    if mask is not None:
        while mask.ndim < 5:
            mask = mask[None]
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if shard_spec is not None:
        probs = _constrain(probs, (shard_spec[0], None, None, shard_spec[1], None))
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(key: jax.Array, d_model: int, n_heads: int, n_kv_heads: int,
             head_dim: int, dtype=jnp.float32, qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    mk = layers.dense_bias_init if qkv_bias else dense_init
    return {
        "wq": mk(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": mk(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": mk(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def gqa_project_qkv(params: Params, x: jax.Array, n_heads: int, n_kv_heads: int,
                    head_dim: int, positions: jax.Array,
                    rope_theta: float = 10000.0, use_rope: bool = True):
    b, s, _ = x.shape
    q = dense(params["wq"], x).reshape(b, s, n_heads, head_dim)
    k = dense(params["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = dense(params["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def jnp_attention(q, k, v, *, causal: bool = True,
                  window: int | None = None,
                  shard_spec: tuple | None = None) -> jax.Array:
    """Reference attention with structured masking (adapter over
    dot_product_attention; same signature family as the Pallas kernel)."""
    mask = causal_mask(q.shape[1], k.shape[1], window=window) if causal else None
    return dot_product_attention(q, k, v, mask, shard_spec=shard_spec)


def gqa_attention(params: Params, x: jax.Array, *, n_heads: int,
                  n_kv_heads: int, head_dim: int, positions: jax.Array,
                  window: int | None = None, rope_theta: float = 10000.0,
                  use_rope: bool = True, attn_impl=None) -> jax.Array:
    """Full (training / prefill) self-attention.  x: (B, S, D)."""
    b, s, _ = x.shape
    q, k, v = gqa_project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                              positions, rope_theta, use_rope)
    impl = attn_impl if attn_impl is not None else jnp_attention
    out = impl(q, k, v, causal=True, window=window)
    return dense(params["wo"], out.reshape(b, s, n_heads * head_dim))


class KVCache(NamedTuple):
    """Decode-time KV cache.  For SWA this is a ring buffer of ``window``."""
    k: jax.Array          # (B, max_len, Hkv, D)
    v: jax.Array          # (B, max_len, Hkv, D)
    length: jax.Array     # () int32 — tokens written so far (absolute)

    @property
    def max_len(self) -> int:
        return self.k.shape[1]


def kv_cache_init(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_len, n_kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def kv_cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                    *, ring: bool = False) -> KVCache:
    """Append S_new tokens (decode S_new==1).  ``ring`` wraps modulo max_len
    (sliding-window cache)."""
    s_new = k_new.shape[1]
    pos = cache.length
    if ring:
        idx = (pos + jnp.arange(s_new)) % cache.max_len
        k = cache.k.at[:, idx].set(k_new.astype(cache.k.dtype))
        v = cache.v.at[:, idx].set(v_new.astype(cache.v.dtype))
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), pos, axis=1)
    return KVCache(k, v, pos + s_new)


def gqa_decode_step(params: Params, x: jax.Array, cache: KVCache, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    window: int | None = None, rope_theta: float = 10000.0,
                    use_rope: bool = True) -> tuple[jax.Array, KVCache]:
    """One-token decode.  x: (B, 1, D).  Attends over the cache + new token."""
    b, s, _ = x.shape
    positions = cache.length + jnp.arange(s)[None, :]  # (1|B, S) absolute
    positions = jnp.broadcast_to(positions, (b, s))
    q, k_new, v_new = gqa_project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                                      positions, rope_theta, use_rope)
    cache = kv_cache_update(cache, k_new, v_new, ring=window is not None)
    # Validity mask over cache slots.
    slot = jnp.arange(cache.max_len)[None, :]
    if window is not None:
        # ring buffer: slot j currently holds the newest token whose absolute
        # position is ≡ j (mod ring size).  A slot is attendable iff that
        # token (a) has been written and (b) is still inside the sliding
        # window of the query (= the token just appended at position
        # length-1).  When the ring is sized exactly to the window
        # (init_cache's layout) this reduces to "every written slot", but
        # deriving it from positions keeps oversized rings correct too.
        last = cache.length - 1
        slot_pos = last - ((last - slot) % cache.max_len)
        valid = (slot_pos >= 0) & (slot_pos > last - window)
    else:
        valid = slot < cache.length
    mask = valid[:, None, None, None, :]  # (1,1,1,1,max_len) -> (B,H,G,S,K)
    out = dot_product_attention(q, cache.k, cache.v, mask)
    y = dense(params["wo"], out.reshape(b, s, n_heads * head_dim))
    return y, cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V3)
# ---------------------------------------------------------------------------

class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


def mla_init(key: jax.Array, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        # query: down-proj -> norm -> up-proj to (nope + rope) dims
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": layers.rmsnorm_init(cfg.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, h * (dn + dr), dtype),
        # kv: joint down-proj to compressed latent + shared rope key
        "wkv_a": dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank + dr, dtype),
        "kv_norm": layers.rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": dense_init(ks[3], cfg.kv_lora_rank, h * (dn + dv), dtype),
        "wo": dense_init(ks[4], h * dv, cfg.d_model, dtype),
    }


def _mla_qkv(params: Params, x: jax.Array, cfg: MLAConfig, positions: jax.Array):
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = dense(params["wq_b"], layers.rmsnorm(params["q_norm"], dense(params["wq_a"], x)))
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions)
    kv_a = dense(params["wkv_a"], x)                       # (B,S,rank+dr)
    c_kv, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    c_kv = layers.rmsnorm(params["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[..., None, :], positions)   # single shared rope head
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def mla_attention(params: Params, x: jax.Array, cfg: MLAConfig,
                  positions: jax.Array) -> jax.Array:
    """Training/prefill MLA; materializes per-head K/V from the latent."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions)
    kv = dense(params["wkv_b"], c_kv).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    scale = 1.0 / math.sqrt(dn + dr)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    mask = causal_mask(s, s)[None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return dense(params["wo"], out.reshape(b, s, h * dv).astype(x.dtype))


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, max_len, kv_lora_rank)
    k_rope: jax.Array  # (B, max_len, qk_rope_dim)
    length: jax.Array

    @property
    def max_len(self) -> int:
        return self.c_kv.shape[1]


def mla_cache_init(batch: int, max_len: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                    jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
                    jnp.zeros((), jnp.int32))


def mla_decode_step(params: Params, x: jax.Array, cache: MLACache,
                    cfg: MLAConfig) -> tuple[jax.Array, MLACache]:
    """One-token MLA decode against the *compressed* cache (absorbed form):

    attention logits are computed in the latent space by absorbing wkv_b's
    K-half into the query — the cache stays (rank + rope) wide, which is the
    whole point of MLA's memory saving.
    """
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(cache.length + jnp.arange(s)[None, :], (b, s))
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, x, cfg, positions)
    pos = cache.length
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv_new.astype(cache.c_kv.dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), pos, axis=1)
    new_cache = MLACache(c_kv, k_rope, pos + s)

    wkv_b = params["wkv_b"]["w"].reshape(cfg.kv_lora_rank, h, dn + dv)
    w_k = wkv_b[..., :dn]   # (rank, h, dn)
    w_v = wkv_b[..., dn:]   # (rank, h, dv)
    # Absorb: q_latent[b,s,h,rank] = q_nope . w_k
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_k,
                       preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(dn + dr)
    logits = (jnp.einsum("bshr,bkr->bhsk", q_lat.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,bkd->bhsk", q_rope.astype(k_rope.dtype),
                           k_rope, preferred_element_type=jnp.float32)) * scale
    valid = (jnp.arange(new_cache.max_len)[None, :] < new_cache.length)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhsk,bkr->bshr", probs.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bshr,rhd->bshd", out_lat.astype(w_v.dtype), w_v,
                     preferred_element_type=jnp.float32)
    y = dense(params["wo"], out.reshape(b, s, h * dv).astype(x.dtype))
    return y, new_cache
