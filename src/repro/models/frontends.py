"""Modality-frontend STUBS for the [audio] and [vlm] architectures.

Per the assignment carve-out: the conv/mel codec (audio) and the ViT/SigLIP
tower (vision) are NOT implemented — ``input_specs()`` hands the backbone
*precomputed* frame/patch embeddings of the right shape.  These helpers
define those shapes and produce deterministic synthetic embeddings for smoke
tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Standard frontend geometries (documented, fixed per arch family):
#  * audio  (SeamlessM4T w2v-BERT codec): 1 frame / 80 ms -> 30 s clip = 375
#    frames; smoke uses 64.
#  * vision (LLaVA-NeXT anyres): base 576 patches (24×24 @ CLIP-L/14 336px)
#    + up to 4 tiles -> 2880 patches; smoke uses 64.
AUDIO_FRAMES = 384
VLM_PATCHES = 576


def frontend_seq(frontend: str, *, smoke: bool = False) -> int:
    if smoke:
        return 16
    return {"audio": AUDIO_FRAMES, "vision": VLM_PATCHES}[frontend]


def synth_embeddings(key: jax.Array, batch: int, seq: int, d_model: int,
                     dtype=jnp.float32) -> jax.Array:
    """Deterministic stand-in for frontend output (unit-RMS embeddings)."""
    x = jax.random.normal(key, (batch, seq, d_model), jnp.float32)
    x = x / jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + 1e-6)
    return x.astype(dtype)
