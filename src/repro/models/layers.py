"""Core layers: initializers, norms, embeddings, rotary position embeddings.

All layers are function pairs over plain-dict params.  Matmul weights are
stored as ``(in, out)`` and applied with ``x @ w`` so that the ``out`` axis is
the natural tensor-parallel shard axis for column-parallel layers and the
``in`` axis for row-parallel layers (see repro.sharding).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp

Params = dict  # nested dict pytree of jnp arrays


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def trunc_normal(key: jax.Array, shape: Sequence[int], std: float,
                 dtype=jnp.float32) -> jax.Array:
    """Truncated-normal initializer (±2 std)."""
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def lecun_normal(key: jax.Array, shape: Sequence[int], fan_in: int | None = None,
                 dtype=jnp.float32) -> jax.Array:
    fan_in = fan_in if fan_in is not None else shape[0]
    return trunc_normal(key, shape, std=1.0 / math.sqrt(max(1, fan_in)), dtype=dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
               std: float | None = None) -> Params:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    return {"w": trunc_normal(key, (d_in, d_out), std=std, dtype=dtype)}


def dense_bias_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> Params:
    p = dense_init(key, d_in, d_out, dtype)
    p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: Params, x: jax.Array) -> jax.Array:
    """``x @ w (+ b)``; client-stacked params ride the same line.

    With ``w`` (K, in, out) against ``x`` (K, B, in) — the batched
    executors' client-stacked route — the matmul broadcasts to a K-batched
    GEMM; only the bias needs an explicit broadcast axis.
    """
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        b = params["b"].astype(x.dtype)
        if b.ndim == 2:          # stacked (K, out): broadcast over the
            b = b.reshape((b.shape[0],) + (1,) * (y.ndim - 2) + (-1,))
        y = y + b                # activation axes between K and out
    return y


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def groupnorm_init(channels: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((channels,), dtype), "bias": jnp.zeros((channels,), dtype)}


def groupnorm(params: Params, x: jax.Array, num_groups: int, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over NHWC inputs (the paper swaps BatchNorm→GroupNorm for FL).

    Shape-agnostic over leading axes: ``(..., H, W, C)`` normalizes per
    (leading..., group) over (H, W, channels-in-group), so the batched
    executors' client-stacked activations ``(K, B, H, W, C)`` — with
    stacked ``(K, C)`` scale/bias — reuse the exact single-client math.
    """
    *lead, h, w, c = x.shape
    dtype = x.dtype
    x = x.astype(jnp.float32).reshape(*lead, h, w, num_groups, c // num_groups)
    mean = jnp.mean(x, axis=(-4, -3, -1), keepdims=True)
    var = jnp.var(x, axis=(-4, -3, -1), keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x.reshape(*lead, h, w, c)
    scale, bias = params["scale"], params["bias"]
    if scale.ndim == 2:          # client-stacked (K, C) against (K, B, H, W, C)
        scale = scale[:, None, None, None, :]
        bias = bias[:, None, None, None, :]
    return (x * scale + bias).astype(dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embedding_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": trunc_normal(key, (vocab, d), std=1.0, dtype=dtype)}


def embed(params: Params, ids: jax.Array, scale: float | None = None) -> jax.Array:
    y = jnp.take(params["table"], ids, axis=0)
    if scale is not None:
        y = y * scale
    return y


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Tied output projection: ``x @ table.T`` -> logits."""
    return x @ params["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply RoPE.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(dense(params["gate"], x))
    return dense(params["down"], g * dense(params["up"], x))


def gelu_mlp_init(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {"up": dense_bias_init(k1, d_model, d_ff, dtype),
            "down": dense_bias_init(k2, d_ff, d_model, dtype)}


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    return dense(params["down"], jax.nn.gelu(dense(params["up"], x)))


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def count_params(params: Any) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Any) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree_util.tree_leaves(params))


def tree_cast(params: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


@partial(jax.jit, static_argnames=())
def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)
