"""ResNet-8 / ResNet-50 with GroupNorm — the paper's CV backbones.

The paper replaces BatchNorm with GroupNorm (16 channels/group) because BN
statistics break under non-IID federated training (Hsieh et al., 2020); we
do the same.  NHWC layout, pure JAX.

``resnet8``  : 3 stages × 1 basic block (16/32/64 ch) — the paper's CIFAR net.
``resnet50`` : standard bottleneck [3,4,6,3] — the paper's Tiny-ImageNet net.

Client-stacked route: every apply/features function here is pytree-pure
over a LEADING CLIENT AXIS — called with per-client stacked params (conv
weights ``(K, kh, kw, Cin, Cout)``, norms ``(K, C)``) and stacked inputs
``(K, B, H, W, C)``, ``conv`` detects the 5-D weights and dispatches to the
fused ``kernels.grouped_conv.client_batched_conv`` (one feature-grouped
conv + custom VJP) instead of K separate convolutions.  That is what lets
the batched executors run a whole cohort's forward+backward as one clean
program rather than vmapping conv weights (which XLA lowers poorly — see
ROADMAP).  Single-client calls are bit-for-bit unchanged.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Params


def conv_init(key: jax.Array, kh: int, kw: int, cin: int, cout: int,
              dtype=jnp.float32) -> Params:
    fan_in = kh * kw * cin
    return {"w": layers.trunc_normal(key, (kh, kw, cin, cout),
                                     std=math.sqrt(2.0 / fan_in), dtype=dtype)}


def conv(params: Params, x: jax.Array, stride: int = 1,
         padding: str = "SAME") -> jax.Array:
    w = params["w"].astype(x.dtype)
    if w.ndim == 5:              # client-stacked (K, kh, kw, Cin, Cout)
        from repro.kernels.grouped_conv import ops as grouped_ops
        return grouped_ops.client_batched_conv(x, w, stride=stride,
                                               padding=padding)
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_groups(c: int, channels_per_group: int = 16) -> int:
    return max(1, c // channels_per_group)


def basic_block_init(key: jax.Array, cin: int, cout: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "conv1": conv_init(ks[0], 3, 3, cin, cout, dtype),
        "gn1": layers.groupnorm_init(cout, dtype),
        "conv2": conv_init(ks[1], 3, 3, cout, cout, dtype),
        "gn2": layers.groupnorm_init(cout, dtype),
    }
    if cin != cout:
        p["proj"] = conv_init(ks[2], 1, 1, cin, cout, dtype)
    return p


def basic_block(params: Params, x: jax.Array, stride: int) -> jax.Array:
    g = _gn_groups(params["gn1"]["scale"].shape[-1])
    y = conv(params["conv1"], x, stride)
    y = jax.nn.relu(layers.groupnorm(params["gn1"], y, g))
    y = conv(params["conv2"], y, 1)
    y = layers.groupnorm(params["gn2"], y, g)
    if "proj" in params:
        x = conv(params["proj"], x, stride)
    elif stride != 1:
        x = x[..., ::stride, ::stride, :]
    return jax.nn.relu(x + y)


def bottleneck_init(key: jax.Array, cin: int, cmid: int, dtype=jnp.float32) -> Params:
    cout = 4 * cmid
    ks = jax.random.split(key, 4)
    p = {
        "conv1": conv_init(ks[0], 1, 1, cin, cmid, dtype),
        "gn1": layers.groupnorm_init(cmid, dtype),
        "conv2": conv_init(ks[1], 3, 3, cmid, cmid, dtype),
        "gn2": layers.groupnorm_init(cmid, dtype),
        "conv3": conv_init(ks[2], 1, 1, cmid, cout, dtype),
        "gn3": layers.groupnorm_init(cout, dtype),
    }
    if cin != cout:
        p["proj"] = conv_init(ks[3], 1, 1, cin, cout, dtype)
    return p


def bottleneck(params: Params, x: jax.Array, stride: int) -> jax.Array:
    c1 = params["gn1"]["scale"].shape[-1]
    c3 = params["gn3"]["scale"].shape[-1]
    y = jax.nn.relu(layers.groupnorm(params["gn1"], conv(params["conv1"], x, 1),
                                     _gn_groups(c1)))
    y = jax.nn.relu(layers.groupnorm(params["gn2"], conv(params["conv2"], y, stride),
                                     _gn_groups(c1)))
    y = layers.groupnorm(params["gn3"], conv(params["conv3"], y, 1), _gn_groups(c3))
    if "proj" in params:
        x = conv(params["proj"], x, stride)
    return jax.nn.relu(x + y)


# ---------------------------------------------------------------------------

def resnet8_init(key: jax.Array, num_classes: int, width: int = 16,
                 dtype=jnp.float32, projection_head: bool = False) -> Params:
    """3 stages × 1 basic block. ~0.08M params at width 16 — the paper's
    CIFAR model scale.  ``projection_head`` adds the 2-layer MLP used by
    MOON / FedGKD+ (SimCLR-style, output dim 256)."""
    ks = jax.random.split(key, 8)
    p: Params = {
        "stem": conv_init(ks[0], 3, 3, 3, width, dtype),
        "gn0": layers.groupnorm_init(width, dtype),
        "block1": basic_block_init(ks[1], width, width, dtype),
        "block2": basic_block_init(ks[2], width, 2 * width, dtype),
        "block3": basic_block_init(ks[3], 2 * width, 4 * width, dtype),
        "fc": layers.dense_bias_init(ks[4], 4 * width, num_classes, dtype),
    }
    if projection_head:
        p["proj_head"] = {
            "fc1": layers.dense_bias_init(ks[5], 4 * width, 4 * width, dtype),
            "fc2": layers.dense_bias_init(ks[6], 4 * width, 256, dtype),
        }
        p["fc"] = layers.dense_bias_init(ks[4], 256, num_classes, dtype)
    return p


def resnet8_features(params: Params, x: jax.Array) -> jax.Array:
    """Penultimate features (the paper's t-SNE layer). x: (N, H, W, 3)."""
    w = params["gn0"]["scale"].shape[-1]
    h = jax.nn.relu(layers.groupnorm(params["gn0"], conv(params["stem"], x, 1),
                                     _gn_groups(w)))
    h = basic_block(params["block1"], h, 1)
    h = basic_block(params["block2"], h, 2)
    h = basic_block(params["block3"], h, 2)
    h = jnp.mean(h, axis=(-3, -2))
    if "proj_head" in params:
        h = jax.nn.relu(layers.dense(params["proj_head"]["fc1"], h))
        h = layers.dense(params["proj_head"]["fc2"], h)
    return h


def resnet8_apply(params: Params, x: jax.Array) -> jax.Array:
    return layers.dense(params["fc"], resnet8_features(params, x))


# ---------------------------------------------------------------------------

_R50_STAGES: Sequence[tuple[int, int]] = ((64, 3), (128, 4), (256, 6), (512, 3))


def resnet50_init(key: jax.Array, num_classes: int, dtype=jnp.float32,
                  projection_head: bool = False) -> Params:
    ks = jax.random.split(key, 24)
    ki = iter(range(24))
    p: Params = {"stem": conv_init(ks[next(ki)], 7, 7, 3, 64, dtype),
                 "gn0": layers.groupnorm_init(64, dtype)}
    cin = 64
    for si, (cmid, blocks) in enumerate(_R50_STAGES):
        for bi in range(blocks):
            p[f"s{si}b{bi}"] = bottleneck_init(ks[next(ki)], cin, cmid, dtype)
            cin = 4 * cmid
    feat = cin
    p["fc"] = layers.dense_bias_init(ks[next(ki)], feat, num_classes, dtype)
    if projection_head:
        p["proj_head"] = {
            "fc1": layers.dense_bias_init(ks[next(ki)], feat, feat, dtype),
            "fc2": layers.dense_bias_init(ks[next(ki)], feat, 256, dtype),
        }
        p["fc"] = layers.dense_bias_init(ks[next(ki)], 256, num_classes, dtype)
    return p


def resnet50_features(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(layers.groupnorm(params["gn0"], conv(params["stem"], x, 2),
                                     _gn_groups(64)))
    lead = (1,) * (h.ndim - 3)     # (N,) or client-stacked (K, B)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, lead + (3, 3, 1),
                              lead + (2, 2, 1), "SAME")
    for si, (cmid, blocks) in enumerate(_R50_STAGES):
        for bi in range(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = bottleneck(params[f"s{si}b{bi}"], h, stride)
    h = jnp.mean(h, axis=(-3, -2))
    if "proj_head" in params:
        h = jax.nn.relu(layers.dense(params["proj_head"]["fc1"], h))
        h = layers.dense(params["proj_head"]["fc2"], h)
    return h


def resnet50_apply(params: Params, x: jax.Array) -> jax.Array:
    return layers.dense(params["fc"], resnet50_features(params, x))


# small MLP for the paper's toy example (Fig. 5)

def mlp_init(key: jax.Array, d_in: int, widths: Sequence[int], num_classes: int,
             dtype=jnp.float32) -> Params:
    dims = [d_in, *widths, num_classes]
    ks = jax.random.split(key, len(dims) - 1)
    return {f"fc{i}": layers.dense_bias_init(ks[i], dims[i], dims[i + 1], dtype)
            for i in range(len(dims) - 1)}


def mlp_apply(params: Params, x: jax.Array) -> jax.Array:
    n = len(params)
    h = x
    for i in range(n):
        h = layers.dense(params[f"fc{i}"], h)
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def mlp_features(params: Params, x: jax.Array) -> jax.Array:
    n = len(params)
    h = x
    for i in range(n - 1):
        h = jax.nn.relu(layers.dense(params[f"fc{i}"], h))
    return h
