"""Mixture-of-Experts: top-k router + capacity-bucketed expert dispatch.

TPU-native design (GShard/Switch style, as used by MaxText/flaxformer):
tokens are dispatched to per-expert capacity buckets with one-hot einsums so
that the whole layer is dense linear algebra on the MXU — the expert axis
``E`` is the natural expert-parallel shard axis ("model" mesh axis).

Memory control: the dispatch/combine tensors are (G, E, C) for a token group
of size ``G``; we scan over groups of ``group_size`` tokens so only one
group's dispatch tensor is live at a time.

Supports Mixtral-style top-2 (softmax-over-topk gates) and DeepSeek-V3 style
(1 shared expert + 256 routed top-8, sigmoid scores renormalized over topk).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Params, dense_init


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    shared_d_ff: int = 0      # hidden of the shared expert (0 -> d_ff)
    capacity_factor: float = 1.25
    group_size: int = 4096    # tokens per dispatch group (memory knob)
    router_type: str = "softmax"  # "softmax" (mixtral) | "sigmoid" (deepseek-v3)
    aux_loss_coef: float = 0.01
    batched_groups: bool = False  # vmap groups instead of lax.scan (exact
    #                               HLO cost accounting for the dry-run probe)
    # optional explicit sharding constraints (beyond-paper §Perf lever):
    # group axis -> dp_axis ("data"), expert axis -> ep_axis ("model").
    dp_axis: object = None        # str | tuple | None
    ep_axis: object = None


def moe_init(key: jax.Array, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        "gate": layers.trunc_normal(ks[1], (e, d, f), std=std, dtype=dtype),
        "up": layers.trunc_normal(ks[2], (e, d, f), std=std, dtype=dtype),
        "down": layers.trunc_normal(ks[3], (e, f, d), std=1.0 / math.sqrt(f), dtype=dtype),
    }
    if cfg.n_shared_experts:
        sf = (cfg.shared_d_ff or cfg.d_ff) * cfg.n_shared_experts
        p["shared"] = layers.swiglu_init(ks[4], d, sf, dtype)
    return p


def router_probs(params: Params, x: jax.Array, cfg: MoEConfig):
    """Return (gates (T,k), expert_idx (T,k), full_probs (T,E)) for flat x (T,D)."""
    logits = layers.dense(params["router"], x.astype(jnp.float32))  # (T,E)
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        top_vals, top_idx = jax.lax.top_k(scores, cfg.top_k)
        gates = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-20)
    else:
        top_vals, top_idx = jax.lax.top_k(logits, cfg.top_k)
        gates = jax.nn.softmax(top_vals, axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)
    return gates, top_idx, probs


def _constrain(x: jax.Array, spec) -> jax.Array:
    """Best-effort sharding constraint (no-op outside a mesh context)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def _dispatch_group(params: Params, xg: jax.Array, cfg: MoEConfig):
    """One token group. xg: (G, D) -> (out (G, D), aux_loss scalar)."""
    g, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(math.ceil(g * k / e * cfg.capacity_factor)))
    gates, top_idx, probs = router_probs(params, xg, cfg)

    # position of each (token, choice) within its expert's capacity bucket
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)          # (G,k,E)
    flat = onehot.reshape(g * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat               # (G*k,E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(g, k)    # (G,k)
    keep = pos < cap                                              # capacity drop
    gates = gates * keep

    # dispatch/combine tensors: (G, E, C)
    cap_onehot = jax.nn.one_hot(pos, cap, dtype=xg.dtype)         # (G,k,C)
    disp = jnp.einsum("gke,gkc->gec", onehot.astype(xg.dtype) * keep[..., None],
                      cap_onehot)
    comb = jnp.einsum("gke,gkc->gec", (onehot * keep[..., None]).astype(jnp.float32)
                      * gates[..., None], cap_onehot.astype(jnp.float32))

    xe = jnp.einsum("gec,gd->ecd", disp, xg)                      # (E,C,D)
    if cfg.ep_axis is not None:
        disp = _constrain(disp, (None, cfg.ep_axis, None))
        comb = _constrain(comb, (None, cfg.ep_axis, None))
        xe = _constrain(xe, (cfg.ep_axis, None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["gate"].astype(xg.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["up"].astype(xg.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, params["down"].astype(xg.dtype))  # (E,C,D)
    if cfg.ep_axis is not None:
        ye = _constrain(ye, (cfg.ep_axis, None, None))
    out = jnp.einsum("gec,ecd->gd", comb.astype(xg.dtype), ye)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f_e = jnp.mean(jnp.sum(onehot * keep[..., None].astype(jnp.int32), axis=1)
                   .astype(jnp.float32), axis=0)                  # (E,)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e) / k
    return out, aux


def moe_apply(params: Params, x: jax.Array, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gsz = min(cfg.group_size, t)
    n_groups = t // gsz
    assert n_groups * gsz == t, f"tokens {t} not divisible by group {gsz}"

    if n_groups == 1:
        out, aux = _dispatch_group(params, xf, cfg)
    elif cfg.batched_groups:
        xg = xf.reshape(n_groups, gsz, d)
        if cfg.dp_axis is not None:
            xg = _constrain(xg, (cfg.dp_axis, None, None))
        out, aux = jax.vmap(lambda xgi: _dispatch_group(params, xgi, cfg))(xg)
        if cfg.dp_axis is not None:
            out = _constrain(out, (cfg.dp_axis, None, None))
        out = out.reshape(t, d)
        aux = jnp.mean(aux)
    else:
        xg = xf.reshape(n_groups, gsz, d)
        if cfg.dp_axis is not None:
            xg = _constrain(xg, (cfg.dp_axis, None, None))

        def body(_, xgi):
            return None, _dispatch_group(params, xgi, cfg)

        _, (out, aux) = jax.lax.scan(body, None, xg)
        out = out.reshape(t, d)
        aux = jnp.mean(aux)

    if cfg.n_shared_experts:
        out = out + layers.swiglu(params["shared"], xf)
    return out.reshape(b, s, d), aux * cfg.aux_loss_coef


def moe_active_params(cfg: MoEConfig) -> int:
    """Per-token active parameter count of the expert block (for MODEL_FLOPS)."""
    routed = 3 * cfg.d_model * cfg.d_ff * cfg.top_k
    shared = 3 * cfg.d_model * (cfg.shared_d_ff or cfg.d_ff) * cfg.n_shared_experts
    router = cfg.d_model * cfg.n_experts
    return routed + shared + router
