"""ModelConfig — the single architecture description consumed by the stack.

One dataclass covers all six assigned architecture families (dense, MoE,
SSM, hybrid, enc-dec, VLM/audio-backbone).  ``segments()`` linearizes the
layer stack into homogeneous runs that ``transformer.py`` scans over.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention
    attn_type: str = "gqa"         # gqa | mla | none
    attn_window: Optional[int] = None   # sliding-window size (Mixtral / long-ctx)
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    use_rope: bool = True

    # MoE
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0         # leading dense layers before MoE layers

    # MLA
    mla: Optional[MLAConfig] = None

    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    shared_attn_period: int = 0    # hybrid: shared attn block every N ssm layers

    # encoder-decoder
    enc_layers: int = 0            # >0 -> enc-dec; encoder is bidirectional

    # modality frontend (stubbed): tokens replaced/prefixed by embeddings
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_seq: int = 0           # #frontend embedding positions (per shape)

    # norm / act / embeddings
    norm: str = "rms"              # rms | ln
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = True
    logits_soft_cap: Optional[float] = None

    # MTP (DeepSeek-V3 multi-token prediction) — extra head depth
    mtp_depth: int = 0

    # activation-sharding constraints (beyond-paper §Perf levers; None = let
    # the SPMD partitioner decide)
    attn_dp_axis: Optional[str] = None   # batch axis of attention scores
    attn_sp_axis: Optional[str] = None   # sequence axis of attention scores
    residual_dp_axis: Optional[str] = None  # Megatron-SP residual stream:
    residual_sp_axis: Optional[str] = None  # (B, S, D) -> (dp, sp, None)

    # execution
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    remat: bool = False
    scan_layers: bool = True
    use_pallas: bool = False
    moe_group_size: int = 4096

    # ----------------------------------------------------------------- utils
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    def segments(self) -> list[tuple[str, int]]:
        """Homogeneous layer runs, in order. Kinds: dense | moe | mamba."""
        if self.family in ("ssm",):
            return [("mamba", self.n_layers)]
        if self.family == "hybrid":
            return [("mamba", self.n_layers)]  # shared attn handled separately
        if self.moe is not None:
            segs = []
            if self.first_k_dense:
                segs.append(("dense", self.first_k_dense))
            segs.append(("moe", self.n_layers - self.first_k_dense))
            return segs
        return [("dense", self.n_layers)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ params math
    def param_count(self) -> int:
        """Analytic total parameter count (for 6·N·D roofline math)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim_
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d

        def attn_params() -> int:
            if self.attn_type == "mla":
                m = self.mla
                q = d * m.q_lora_rank + m.q_lora_rank * m.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                kv = d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank * m.n_heads * (m.qk_nope_dim + m.v_head_dim)
                o = m.n_heads * m.v_head_dim * d
                return q + kv + o
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff if self.act == "swiglu" else 2 * d * ff

        def moe_params() -> int:
            m = self.moe
            routed = m.n_experts * 3 * d * m.d_ff + d * m.n_experts
            shared = m.n_shared_experts * 3 * d * (m.shared_d_ff or m.d_ff)
            return routed + shared

        for kind, count in self.segments():
            if kind == "dense":
                ff = self.d_ff
                total += count * (attn_params() + mlp_params(ff) + 2 * d)
            elif kind == "moe":
                total += count * (attn_params() + moe_params() + 2 * d)
            elif kind == "mamba":
                s = self.ssm
                di, g, n = s.d_inner, s.n_groups, s.d_state
                per = (d * (2 * di + 2 * g * n + s.n_heads)       # in_proj
                       + s.d_conv * (di + 2 * g * n)              # conv
                       + di * d + 2 * s.n_heads + di + d)         # out_proj+A/D/norm
                total += count * per
        if self.family == "hybrid" and self.shared_attn_period:
            total += attn_params() + mlp_params(self.d_ff) + 2 * d + 2 * d * d
        if self.mtp_depth:
            # proj(2d->d) + one dense block + 3 norms
            total += self.mtp_depth * (2 * d * d + attn_params()
                                       + mlp_params(self.d_ff) + 5 * d)
        if self.enc_layers:
            # encoder self-attn+mlp and decoder cross-attn
            total += self.enc_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            total += self.n_layers * (attn_params() + d)
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only top-k experts."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_moe = m.n_experts * 3 * self.d_model * m.d_ff
        active_moe = m.top_k * 3 * self.d_model * m.d_ff
        n_moe_layers = self.n_layers - self.first_k_dense
        return self.param_count() - n_moe_layers * (full_moe - active_moe)
