"""Unified model stack covering all assigned architecture families.

A model is built from ``ModelConfig.segments()`` — homogeneous runs of
layers ("dense" attn+MLP, "moe" attn+MoE, "mamba" SSD) whose parameters are
stacked on a leading layer axis and executed with ``jax.lax.scan`` (compile
time stays flat in depth; remat is a per-block ``jax.checkpoint``).

Public entry points
  init(rng, cfg)                          -> params
  forward(params, cfg, tokens, ...)      -> (logits, aux_loss)
  encode(params, cfg, enc_embeddings)    -> encoder output (enc-dec only)
  init_cache(cfg, batch, max_len)        -> decode cache pytree
  decode_step(params, cfg, tokens, cache [, enc_out]) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# norm / mlp dispatch
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig, d: int) -> Params:
    return layers.rmsnorm_init(d, cfg.pdtype) if cfg.norm == "rms" \
        else layers.layernorm_init(d, cfg.pdtype)


def _norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    return layers.rmsnorm(p, x) if cfg.norm == "rms" else layers.layernorm(p, x)


def _mlp_init(cfg: ModelConfig, key: jax.Array, d_ff: int) -> Params:
    if cfg.act == "swiglu":
        return layers.swiglu_init(key, cfg.d_model, d_ff, cfg.pdtype)
    return layers.gelu_mlp_init(key, cfg.d_model, d_ff, cfg.pdtype)


def _mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    return layers.swiglu(p, x) if cfg.act == "swiglu" else layers.gelu_mlp(p, x)


def _attn_impl(cfg: ModelConfig):
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention_gqa
    if cfg.attn_dp_axis or cfg.attn_sp_axis:
        spec = (cfg.attn_dp_axis, cfg.attn_sp_axis)
        return functools.partial(attn_lib.jnp_attention, shard_spec=spec)
    return attn_lib.jnp_attention


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _attn_init(cfg: ModelConfig, key: jax.Array) -> Params:
    if cfg.attn_type == "mla":
        return attn_lib.mla_init(key, cfg.mla, cfg.pdtype)
    return attn_lib.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim_, cfg.pdtype, cfg.qkv_bias)


def _attn_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                positions: jax.Array, window: Optional[int]) -> jax.Array:
    if cfg.attn_type == "mla":
        return attn_lib.mla_attention(p, x, cfg.mla, positions)
    return attn_lib.gqa_attention(
        p, x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim_, positions=positions, window=window,
        rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
        attn_impl=_attn_impl(cfg))


def _dense_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": _norm_init(cfg, cfg.d_model), "attn": _attn_init(cfg, k1),
            "norm2": _norm_init(cfg, cfg.d_model), "mlp": _mlp_init(cfg, k2, cfg.d_ff)}


def _dense_layer(cfg: ModelConfig, p: Params, h: jax.Array,
                 positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    h = h + _attn_apply(cfg, p["attn"], _norm(cfg, p["norm1"], h), positions,
                        cfg.attn_window)
    h = h + _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], h))
    return h, jnp.zeros((), jnp.float32)


def _moe_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": _norm_init(cfg, cfg.d_model), "attn": _attn_init(cfg, k1),
            "norm2": _norm_init(cfg, cfg.d_model),
            "moe": moe_lib.moe_init(k2, cfg.moe, cfg.pdtype)}


def _moe_layer(cfg: ModelConfig, p: Params, h: jax.Array,
               positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    h = h + _attn_apply(cfg, p["attn"], _norm(cfg, p["norm1"], h), positions,
                        cfg.attn_window)
    mcfg = cfg.moe._replace(group_size=cfg.moe_group_size)
    out, aux = moe_lib.moe_apply(p["moe"], _norm(cfg, p["norm2"], h), mcfg)
    return h + out, aux


def _mamba_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    return {"norm": _norm_init(cfg, cfg.d_model),
            "mixer": ssm_lib.mamba2_init(key, cfg.ssm, cfg.pdtype)}


def _mamba_layer(cfg: ModelConfig, p: Params, h: jax.Array,
                 positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    out, _ = ssm_lib.mamba2_forward(p["mixer"], _norm(cfg, p["norm"], h), cfg.ssm)
    return h + out, jnp.zeros((), jnp.float32)


_LAYER_INIT = {"dense": _dense_layer_init, "moe": _moe_layer_init,
               "mamba": _mamba_layer_init}
_LAYER_APPLY = {"dense": _dense_layer, "moe": _moe_layer, "mamba": _mamba_layer}


# hybrid (Zamba2): shared attention block applied every `shared_attn_period`
# mamba layers; input is [h ; h0] projected back to d_model (the Zamba trick
# of re-injecting the embedding stream).

def _shared_block_init(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"in_proj": layers.dense_init(k1, 2 * cfg.d_model, cfg.d_model, cfg.pdtype),
            "norm1": _norm_init(cfg, cfg.d_model), "attn": _attn_init(cfg, k2),
            "norm2": _norm_init(cfg, cfg.d_model), "mlp": _mlp_init(cfg, k3, cfg.d_ff)}


def _shared_block(cfg: ModelConfig, p: Params, h: jax.Array, h0: jax.Array,
                  positions: jax.Array) -> jax.Array:
    x = layers.dense(p["in_proj"], jnp.concatenate([h, h0], axis=-1))
    x = x + _attn_apply(cfg, p["attn"], _norm(cfg, p["norm1"], x), positions, None)
    x = x + _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], x))
    return h + x


# ---------------------------------------------------------------------------
# encoder (enc-dec models) and cross-attention decoder layers
# ---------------------------------------------------------------------------

def _enc_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    return _dense_layer_init(cfg, key)


def _enc_layer(cfg: ModelConfig, p: Params, h: jax.Array,
               positions: jax.Array) -> jax.Array:
    # bidirectional self-attention (no mask)
    b, s, _ = h.shape
    x = _norm(cfg, p["norm1"], h)
    q, k, v = attn_lib.gqa_project_qkv(p["attn"], x, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim_, positions, cfg.rope_theta,
                                       cfg.use_rope)
    out = _attn_impl(cfg)(q, k, v, causal=False)
    h = h + layers.dense(p["attn"]["wo"], out.reshape(b, s, -1))
    h = h + _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], h))
    return h


def _xattn_layer_init(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = _dense_layer_init(cfg, k1)
    p["norm_x"] = _norm_init(cfg, cfg.d_model)
    p["xattn"] = attn_lib.gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim_, cfg.pdtype)
    del k3
    return p


def _cross_attend(cfg: ModelConfig, p: Params, x: jax.Array,
                  enc_out: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    se = enc_out.shape[1]
    hd = cfg.head_dim_
    q = layers.dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = layers.dense(p["wk"], enc_out).reshape(b, se, cfg.n_kv_heads, hd)
    v = layers.dense(p["wv"], enc_out).reshape(b, se, cfg.n_kv_heads, hd)
    out = _attn_impl(cfg)(q, k, v, causal=False)
    return layers.dense(p["wo"], out.reshape(b, s, cfg.n_heads * hd))


def _xattn_layer(cfg: ModelConfig, p: Params, h: jax.Array, positions: jax.Array,
                 enc_out: jax.Array) -> tuple[jax.Array, jax.Array]:
    h = h + _attn_apply(cfg, p["attn"], _norm(cfg, p["norm1"], h), positions, None)
    h = h + _cross_attend(cfg, p["xattn"], _norm(cfg, p["norm_x"], h), enc_out)
    h = h + _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], h))
    return h, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# stacked init + scan
# ---------------------------------------------------------------------------

def _stack_init(fn, key: jax.Array, count: int) -> Params:
    keys = jax.random.split(key, count)
    return jax.vmap(fn)(keys)


def init(rng: jax.Array, cfg: ModelConfig) -> Params:
    """Initialize all parameters for the configured model."""
    n_keys = 8 + len(cfg.segments())
    ks = list(jax.random.split(rng, n_keys))
    params: Params = {
        "embed": layers.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(ks[1], cfg.d_model, cfg.vocab_size, cfg.pdtype)

    dec_layer_init = _xattn_layer_init if cfg.enc_layers else None
    for i, (kind, count) in enumerate(cfg.segments()):
        fn = dec_layer_init if (cfg.enc_layers and kind == "dense") \
            else _LAYER_INIT[kind]
        params[f"seg{i}"] = _stack_init(functools.partial(fn, cfg), ks[2 + i], count)

    if cfg.family == "hybrid" and cfg.shared_attn_period:
        params["shared_block"] = _shared_block_init(cfg, ks[-1])
    if cfg.enc_layers:
        params["enc_embed_norm"] = _norm_init(cfg, cfg.d_model)
        params["enc"] = _stack_init(functools.partial(_enc_layer_init, cfg),
                                    ks[-2], cfg.enc_layers)
        params["enc_final_norm"] = _norm_init(cfg, cfg.d_model)
    if cfg.mtp_depth:
        k1, k2 = jax.random.split(ks[-3])
        params["mtp"] = {
            "proj": layers.dense_init(k1, 2 * cfg.d_model, cfg.d_model, cfg.pdtype),
            "norm_h": _norm_init(cfg, cfg.d_model),
            "norm_e": _norm_init(cfg, cfg.d_model),
            "block": _dense_layer_init(cfg, k2),
            "final_norm": _norm_init(cfg, cfg.d_model),
        }
    return params


def _scan_segment(cfg: ModelConfig, kind: str, seg_params: Params, h: jax.Array,
                  positions: jax.Array, enc_out: Optional[jax.Array] = None,
                  h0: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Run a stacked segment with lax.scan; returns (h, summed aux loss)."""
    if cfg.enc_layers and kind == "dense":
        base = lambda p, h: _xattn_layer(cfg, p, h, positions, enc_out)
    else:
        base = lambda p, h: _LAYER_APPLY[kind](cfg, p, h, positions)
    if cfg.residual_dp_axis or cfg.residual_sp_axis:
        spec = (cfg.residual_dp_axis, cfg.residual_sp_axis, None)

        def layer(p, h):
            h, aux = base(p, attn_lib._constrain(h, spec))
            return attn_lib._constrain(h, spec), aux
    else:
        layer = base
    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)

    if not cfg.scan_layers:
        aux_total = jnp.zeros((), jnp.float32)
        count = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
        for i in range(count):
            p_i = jax.tree_util.tree_map(lambda x: x[i], seg_params)
            h, aux = layer(p_i, h)
            aux_total = aux_total + aux
        return h, aux_total

    def body(h, p):
        h, aux = layer(p, h)
        return h, aux

    h, auxs = jax.lax.scan(body, h, seg_params)
    return h, jnp.sum(auxs)


def _hybrid_stack(cfg: ModelConfig, params: Params, h: jax.Array,
                  positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Zamba2-style: groups of `period` mamba layers + one shared attn block."""
    seg = params["seg0"]
    period = cfg.shared_attn_period
    n = cfg.n_layers
    groups, rem = divmod(n, period)
    h0 = h
    take = lambda tree, a, b: jax.tree_util.tree_map(lambda x: x[a:b], tree)

    if groups:
        if cfg.scan_layers:
            grouped = jax.tree_util.tree_map(
                lambda x: x[: groups * period].reshape(
                    (groups, period) + x.shape[1:]), seg)

            def outer(h, gp):
                h, _ = _scan_segment(cfg, "mamba", gp, h, positions)
                h = _shared_block(cfg, params["shared_block"], h, h0, positions)
                return h, jnp.zeros((), jnp.float32)

            h, _ = jax.lax.scan(outer, h, grouped)
        else:
            for gi in range(groups):
                gp = take(seg, gi * period, (gi + 1) * period)
                h, _ = _scan_segment(cfg, "mamba", gp, h, positions)
                h = _shared_block(cfg, params["shared_block"], h, h0, positions)
    if rem:
        h, _ = _scan_segment(cfg, "mamba", take(seg, groups * period, n), h, positions)
    return h, jnp.zeros((), jnp.float32)


def encode(params: Params, cfg: ModelConfig, enc_embeddings: jax.Array) -> jax.Array:
    """Encoder for enc-dec models. enc_embeddings: (B, S_enc, D) frontend output."""
    h = _norm(cfg, params["enc_embed_norm"], enc_embeddings.astype(cfg.adtype))
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    layer = lambda p, h: (_enc_layer(cfg, p, h, positions), jnp.zeros((), jnp.float32))
    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)

    if cfg.scan_layers:
        def body(h, p):
            return layer(p, h)

        h, _ = jax.lax.scan(body, h, params["enc"])
    else:
        for i in range(cfg.enc_layers):
            p_i = jax.tree_util.tree_map(lambda x: x[i], params["enc"])
            h, _ = layer(p_i, h)
    return _norm(cfg, params["enc_final_norm"], h)


def _embed_inputs(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  prefix_embeddings: Optional[jax.Array]) -> jax.Array:
    h = layers.embed(params["embed"], tokens).astype(cfg.adtype)
    if prefix_embeddings is not None:
        h = jnp.concatenate([prefix_embeddings.astype(cfg.adtype), h], axis=1)
    return h


def hidden_states(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  prefix_embeddings: Optional[jax.Array] = None,
                  enc_out: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Final-norm'ed hidden states (B, S, D) and summed aux loss."""
    h = _embed_inputs(params, cfg, tokens, prefix_embeddings)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        h, a = _hybrid_stack(cfg, params, h, positions)
        aux += a
    else:
        for i, (kind, _) in enumerate(cfg.segments()):
            h, a = _scan_segment(cfg, kind, params[f"seg{i}"], h, positions, enc_out)
            aux += a
    return _norm(cfg, params["final_norm"], h), aux


def logits_from_hidden(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], h)
    else:
        logits = layers.dense(params["head"], h)
    if cfg.logits_soft_cap:
        logits = cfg.logits_soft_cap * jnp.tanh(logits / cfg.logits_soft_cap)
    return logits.astype(jnp.float32)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeddings: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Full forward. Returns (logits (B, S_total, V) fp32, aux_loss)."""
    h, aux = hidden_states(params, cfg, tokens, prefix_embeddings, enc_out)
    return logits_from_hidden(params, cfg, h), aux


def mtp_logits(params: Params, cfg: ModelConfig, h: jax.Array,
               next_tokens: jax.Array) -> jax.Array:
    """DeepSeek-V3 multi-token-prediction head (depth 1): predicts t+2 from
    the trunk hidden state at t combined with the embedding of token t+1."""
    p = params["mtp"]
    emb = layers.embed(params["embed"], next_tokens).astype(h.dtype)
    x = layers.dense(p["proj"], jnp.concatenate(
        [_norm(cfg, p["norm_h"], h), _norm(cfg, p["norm_e"], emb)], axis=-1))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, _ = _dense_layer(cfg, p["block"], x, positions)
    return logits_from_hidden(params, cfg, _norm(cfg, p["final_norm"], x))


# ---------------------------------------------------------------------------
# decode path (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Any:
    """Per-segment stacked caches (leading axis = layer)."""
    caches = {}
    for i, (kind, count) in enumerate(cfg.segments()):
        if kind == "mamba":
            one = ssm_lib.ssm_cache_init(batch, cfg.ssm, dtype)
        elif cfg.attn_type == "mla":
            one = attn_lib.mla_cache_init(batch, max_len, cfg.mla, dtype)
        else:
            window = cfg.attn_window
            cache_len = min(max_len, window) if window else max_len
            one = attn_lib.kv_cache_init(batch, cache_len, cfg.n_kv_heads,
                                         cfg.head_dim_, dtype)
        caches[f"seg{i}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (count,) + x.shape), one)
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        caches["shared"] = attn_lib.kv_cache_init(
            batch, max_len, cfg.n_kv_heads, cfg.head_dim_, dtype)
        caches["shared"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None],
                                       (cfg.n_layers // cfg.shared_attn_period,)
                                       + x.shape), caches["shared"])
    # absolute position counter shared across layers
    caches["pos"] = jnp.zeros((), jnp.int32)
    return caches


def _layer_decode(cfg: ModelConfig, kind: str, p: Params, h: jax.Array,
                  cache, pos: jax.Array, enc_out: Optional[jax.Array]):
    if kind == "mamba":
        out, new_cache = ssm_lib.mamba2_decode_step(
            p["mixer"], _norm(cfg, p["norm"], h), cache, cfg.ssm)
        return h + out, new_cache
    if cfg.attn_type == "mla":
        out, new_cache = attn_lib.mla_decode_step(
            p["attn"], _norm(cfg, p["norm1"], h), cache, cfg.mla)
    else:
        out, new_cache = attn_lib.gqa_decode_step(
            p["attn"], _norm(cfg, p["norm1"], h), cache,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
            window=cfg.attn_window, rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
    h = h + out
    if cfg.enc_layers:
        h = h + _cross_attend(cfg, p["xattn"], _norm(cfg, p["norm_x"], h), enc_out)
    if kind == "moe":
        mcfg = cfg.moe._replace(group_size=cfg.moe_group_size)
        out, _ = moe_lib.moe_apply(p["moe"], _norm(cfg, p["norm2"], h), mcfg)
    else:
        out = _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], h))
    return h + out, new_cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array, cache,
                enc_out: Optional[jax.Array] = None):
    """One-token decode.  tokens: (B, 1).  Returns (logits (B,1,V), cache)."""
    h = layers.embed(params["embed"], tokens).astype(cfg.adtype)
    pos = cache["pos"]
    new_caches = dict(cache)

    if cfg.family == "hybrid" and cfg.shared_attn_period:
        h0 = h
        seg, shared = params["seg0"], cache["seg0"]
        period = cfg.shared_attn_period
        groups = cfg.n_layers // period

        def scan_mamba(h, gp, gc):
            if cfg.scan_layers:
                def body(carry, xs):
                    h, = carry
                    p, c = xs
                    h, new_c = _layer_decode(cfg, "mamba", p, h, c, pos, None)
                    return (h,), new_c

                (h,), new_gc = jax.lax.scan(body, (h,), (gp, gc))
                return h, new_gc
            ncs = []
            count = jax.tree_util.tree_leaves(gp)[0].shape[0]
            for li in range(count):
                p_i = jax.tree_util.tree_map(lambda x: x[li], gp)
                c_i = jax.tree_util.tree_map(lambda x: x[li], gc)
                h, nc = _layer_decode(cfg, "mamba", p_i, h, c_i, pos, None)
                ncs.append(nc)
            return h, jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *ncs)

        # interleave: run in python over groups (params sliced) to keep shared
        # block applications explicit; mamba groups scan (or unroll for the
        # cost probe).
        take = lambda tree, a, b: jax.tree_util.tree_map(lambda x: x[a:b], tree)
        shared_caches = []
        for gi in range(groups):
            gp = take(seg, gi * period, (gi + 1) * period)
            gc = take(cache["seg0"], gi * period, (gi + 1) * period)
            h, new_gc = scan_mamba(h, gp, gc)
            new_caches.setdefault("_seg0_parts", []).append(new_gc)
            # shared attn block with its own kv cache
            sc = jax.tree_util.tree_map(lambda x: x[gi], cache["shared"])
            x = layers.dense(params["shared_block"]["in_proj"],
                             jnp.concatenate([h, h0], axis=-1))
            out, new_sc = attn_lib.gqa_decode_step(
                params["shared_block"]["attn"],
                _norm(cfg, params["shared_block"]["norm1"], x), sc,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                use_rope=cfg.use_rope)
            x = x + out
            x = x + _mlp(cfg, params["shared_block"]["mlp"],
                         _norm(cfg, params["shared_block"]["norm2"], x))
            h = h + x
            shared_caches.append(new_sc)
        rem = cfg.n_layers - groups * period
        if rem:
            gp = take(seg, groups * period, cfg.n_layers)
            gc = take(cache["seg0"], groups * period, cfg.n_layers)
            h, new_gc = scan_mamba(h, gp, gc)
            new_caches["_seg0_parts"].append(new_gc)
        parts = new_caches.pop("_seg0_parts")
        new_caches["seg0"] = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        new_caches["shared"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *shared_caches)
    else:
        for i, (kind, count) in enumerate(cfg.segments()):
            if cfg.scan_layers:
                def body(carry, xs):
                    h, = carry
                    p, c = xs
                    h, new_c = _layer_decode(cfg, kind, p, h, c, pos, enc_out)
                    return (h,), new_c

                (h,), new_c = jax.lax.scan(
                    body, (h,), (params[f"seg{i}"], cache[f"seg{i}"]))
            else:
                ncs = []
                for li in range(count):
                    p_i = jax.tree_util.tree_map(lambda x: x[li],
                                                 params[f"seg{i}"])
                    c_i = jax.tree_util.tree_map(lambda x: x[li],
                                                 cache[f"seg{i}"])
                    h, nc = _layer_decode(cfg, kind, p_i, h, c_i, pos, enc_out)
                    ncs.append(nc)
                new_c = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs, axis=0), *ncs)
            new_caches[f"seg{i}"] = new_c

    new_caches["pos"] = pos + tokens.shape[1]
    h = _norm(cfg, params["final_norm"], h)
    return logits_from_hidden(params, cfg, h), new_caches
