"""Mamba-2 (SSD — state-space duality) block, pure-JAX reference.

The chunked SSD algorithm (Dao & Gu, 2024) splits the sequence into chunks of
``chunk`` tokens; within a chunk the recurrence is computed in its dual
"attention-like" quadratic form (MXU-friendly), and a small per-chunk state
(H, P, N) is carried between chunks with an associative recurrence.  This is
exactly the structure the Pallas ``ssd_scan`` kernel tiles for VMEM; this
module is the jnp oracle and the training/prefill path on CPU.

Decode carries (conv_state, ssm_state) and is O(1) per token.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Params, dense_init


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256
    n_groups: int = 1           # B/C groups (ngroups)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_init(key: jax.Array, cfg: SSMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    g = cfg.n_groups
    d_in_proj = 2 * di + 2 * g * n + h     # z, x, B, C, dt
    conv_dim = di + 2 * g * n              # conv over x, B, C
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[2], (h,)) *
                 (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": layers.trunc_normal(ks[1], (cfg.d_conv, conv_dim),
                                      std=1.0 / math.sqrt(cfg.d_conv), dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "norm": layers.rmsnorm_init(di, dtype),
        "out_proj": dense_init(ks[5], di, cfg.d_model, dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    t = x.shape[-1]
    x_cum = jnp.cumsum(x, axis=-1)
    diff = x_cum[..., :, None] - x_cum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: jax.Array | None = None):
    """Chunked SSD scan.

    x:  (b, l, h, p)   inputs per head
    dt: (b, l, h)      positive step sizes (already softplus'ed + bias)
    A:  (h,)           negative decay rates
    B:  (b, l, g, n)   input matrices (g groups broadcast over heads)
    C:  (b, l, g, n)
    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    pad = (-l) % chunk
    if pad:  # zero-pad: dt=0 rows are identity steps (no state change)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, final = ssd_chunked(x, dt, A, B, C, chunk, init_state)
        return y[:, :l], final
    nc = l // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)  # (b,nc,c,h,n)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = dtc * A[None, None, None, :]                 # (b,nc,c,h) negative
    dA_cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum

    # 1) diagonal (intra-chunk) block: dual attention form
    L = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))      # (b,nc,h,c,c)
    # attention-like weights: C_i . B_j * exp(sum_{j<k<=i} dA_k) * dt_j
    G = jnp.einsum("bzihn,bzjhn->bzhij", Cc, Bc)      # (b,nc,h,c,c)
    M = G * L                                          # decay applied
    y_diag = jnp.einsum("bzhij,bzjh,bzjhp->bzihp", M, dtc, xc)

    # 2) per-chunk final states
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,c,h)
    states = jnp.einsum("bzchn,bzch,bzch,bzchp->bzhpn",
                        Bc, dtc, decay_to_end, xc)          # (b,nc,h,p,n)

    # 3) inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))              # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), x.dtype) if init_state is None
          else init_state.astype(x.dtype))

    def step(carry, inp):
        st, dec = inp           # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry       # emit state *entering* this chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b,nc,h,p,n)

    # 4) contribution of the incoming state to each position
    state_decay = jnp.exp(dA_cum)                           # (b,nc,c,h)
    y_off = jnp.einsum("bzchn,bzhpn,bzch->bzchp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def ssd_reference(x, dt, A, B, C):
    """O(L) sequential reference (ground truth for tests)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bf = jnp.repeat(B, rep, axis=2)
    Cf = jnp.repeat(C, rep, axis=2)

    def step(state, inp):
        xi, dti, Bi, Ci = inp     # (b,h,p),(b,h),(b,h,n),(b,h,n)
        dA = jnp.exp(dti * A)     # (b,h)
        state = state * dA[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dti, Bi, xi)
        y = jnp.einsum("bhn,bhpn->bhp", Ci, state)
        return state, y

    s0 = jnp.zeros((b, h, p, n), x.dtype)
    _, ys = jax.lax.scan(step, s0, (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                                    jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)


class SSMCache(NamedTuple):
    conv_state: jax.Array   # (B, d_conv-1, conv_dim)
    ssm_state: jax.Array    # (B, H, P, N)
    length: jax.Array


def ssm_cache_init(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> SSMCache:
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return SSMCache(
        jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        jnp.zeros((), jnp.int32))


def _split_in_proj(z_x_b_c_dt: jax.Array, cfg: SSMConfig):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = z_x_b_c_dt[..., :di]
    xbc = z_x_b_c_dt[..., di:di + di + 2 * g * n]
    dt = z_x_b_c_dt[..., di + di + 2 * g * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B, L, C), w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def mamba2_forward(params: Params, x: jax.Array, cfg: SSMConfig,
                   init_state: jax.Array | None = None):
    """x: (B, L, D) -> (y (B,L,D), final ssm state)."""
    b, l, d = x.shape
    proj = layers.dense(params["in_proj"], x)
    z, xbc, dt = _split_in_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype))
    di, g, n = cfg.d_inner, cfg.n_groups, cfg.d_state
    xs = xbc[..., :di].reshape(b, l, cfg.n_heads, cfg.head_dim)
    B = xbc[..., di:di + g * n].reshape(b, l, g, n)
    C = xbc[..., di + g * n:].reshape(b, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    y, final = ssd_chunked(xs.astype(jnp.float32), dt, A,
                           B.astype(jnp.float32), C.astype(jnp.float32),
                           chunk=min(cfg.chunk, l), init_state=init_state)
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, l, di).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return layers.dense(params["out_proj"], y), final


def mamba2_decode_step(params: Params, x: jax.Array, cache: SSMCache,
                       cfg: SSMConfig):
    """One-token decode. x: (B, 1, D)."""
    b, s, d = x.shape
    assert s == 1
    proj = layers.dense(params["in_proj"], x)[:, 0]          # (B, d_in_proj)
    z, xbc, dt = _split_in_proj(proj, cfg)
    # causal conv via rolling state
    conv_in = jnp.concatenate([cache.conv_state, xbc[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"].astype(x.dtype)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w)
                      + params["conv_b"].astype(x.dtype)[None, :])
    new_conv_state = conv_in[:, 1:, :]

    di, g, n = cfg.d_inner, cfg.n_groups, cfg.d_state
    xs = xbc[..., :di].reshape(b, cfg.n_heads, cfg.head_dim).astype(jnp.float32)
    B = xbc[..., di:di + g * n].reshape(b, g, n).astype(jnp.float32)
    C = xbc[..., di + g * n:].reshape(b, g, n).astype(jnp.float32)
    rep = cfg.n_heads // g
    B = jnp.repeat(B, rep, axis=1)
    C = jnp.repeat(C, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                             # (B,H)
    state = cache.ssm_state * dA[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, B, xs)
    y = jnp.einsum("bhn,bhpn->bhp", C, state)
    y = y + xs * params["D"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = layers.dense(params["out_proj"], y)[:, None, :]
    return out, SSMCache(new_conv_state, state, cache.length + 1)
