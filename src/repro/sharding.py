"""Sharding rules: parameter/activation PartitionSpecs for the mesh axes.

Mesh axes
    "model"          tensor/expert parallel (16-way per pod)
    "data"           batch / federated-client parallel (16-way per pod)
    "pod"            cross-pod data parallel (multi-pod dry-run)

Strategy (Megatron-style TP + optional ZeRO/FSDP over "data"):
    * column-parallel:  attention q/k/v, MLP gate/up, SSM in_proj  -> out dim
      on "model"
    * row-parallel:     attention wo, MLP down, SSM out_proj       -> in dim
      on "model"
    * vocab-parallel:   embedding table / output head              -> vocab
      on "model"
    * expert-parallel:  MoE expert stacks                          -> E on
      "model"
    * head-parallel:    SSD per-head params (A, D, dt_bias)        -> H on
      "model" (SSD is head-independent, so the scan shards cleanly)
    * fsdp=True additionally shards the largest replicated dim of every
      ≥2D weight over "data" (param + optimizer state) — required for the
      biggest assigned archs (deepseek-v3-671b does not fit TP-only).

Stacked (scan-over-layers) params carry a leading layer axis -> spec gets a
leading None.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def data_axes(mesh: Mesh) -> tuple:
    """The data-parallel axis (grouped with 'pod' when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_mesh_compat(shape: tuple[int, ...], axis_names: tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` across jax versions.

    ``jax.make_mesh`` (with its device-order heuristics) appeared in
    jax 0.4.35; on older releases fall back to
    ``mesh_utils.create_device_mesh`` + the ``Mesh`` constructor, which is
    what ``make_mesh`` wraps.  Every mesh in this repo (production pods,
    host test meshes, the executor's ``("clients",)`` mesh) goes through
    here so a jax bump only has one seam to patch.
    """
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        return mk(shape, axis_names)
    from jax.experimental import mesh_utils
    # match make_mesh: a mesh smaller than the visible device set takes the
    # first prod(shape) devices (create_device_mesh would raise instead)
    n = int(np.prod(shape))
    devs = mesh_utils.create_device_mesh(shape, devices=jax.devices()[:n])
    return Mesh(devs, axis_names)


def make_array_from_process_local_data_compat(sharding: NamedSharding,
                                              local_data,
                                              global_shape: "tuple | None"
                                              = None):
    """``jax.make_array_from_process_local_data`` across jax versions.

    The multi-process cohort-assembly primitive: each host contributes the
    slice its devices own and jax stitches the global sharded array.  The
    public API appeared in jax 0.4.31 (the ``global_shape`` parameter
    became optional later); on releases without it — or without
    multi-process support at all — a single-process topology falls back to
    ``jax.device_put`` onto the sharding, which is exactly what the
    primitive degenerates to when every shard is process-local.  Lives
    next to ``make_mesh_compat`` so a jax bump has one seam to patch.
    """
    fn = getattr(jax, "make_array_from_process_local_data", None)
    if fn is not None:
        try:
            return fn(sharding, local_data, global_shape)
        except TypeError:       # pre-0.4.35 signature: no global_shape arg
            if global_shape is not None:
                raise
            return fn(sharding, local_data)
    if jax.process_count() != 1:
        raise RuntimeError(
            "this jax release has no make_array_from_process_local_data "
            "but the topology is multi-process — upgrade jax (>= 0.4.31)")
    return jax.device_put(local_data, sharding)


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map`` (replication checking via
    ``check_vma``); older releases only have
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  Every
    caller in this repo wants checking off (weights enter replicated but are
    consumed per-shard), so the flag is hard-wired here.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:  # pre-0.6: the kwarg is check_rep
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL_KEYS = ("wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b",
             "gate", "up", "in_proj", "fc1", "head", "proj")
_ROW_KEYS = ("wo", "down", "out_proj", "fc2")


def _spec_for(path: tuple[str, ...], shape: tuple[int, ...],
              stacked: bool, fsdp: bool) -> P:
    """PartitionSpec for one weight, by its param-tree path."""
    names = [p for p in path]
    leaf = names[-1]            # 'w' | 'b' | 'scale' | 'table' | tensor name
    parent = names[-2] if len(names) >= 2 else ""

    def with_stack(spec_dims: list):
        dims = ([None] + spec_dims) if stacked else spec_dims
        return P(*dims)

    ndim = len(shape) - (1 if stacked else 0)

    # embeddings: vocab-parallel
    if leaf == "table":
        return with_stack(["model", "data" if fsdp else None])

    # MoE expert stacks (E, D, F)/(E, F, D): expert-parallel on E
    if parent == "moe" or (leaf in ("gate", "up", "down") and ndim == 3):
        return with_stack(["model", "data" if fsdp else None, None])

    # conv weights (resnet / mamba conv): replicate K, shard channels
    if leaf == "conv_w":
        return with_stack([None, "model"])
    if leaf == "conv_b":
        return with_stack(["model"])
    if leaf in ("A_log", "D", "dt_bias"):
        return with_stack(["model"])

    if leaf == "b":             # bias of a col-parallel layer
        if parent in _COL_KEYS:
            return with_stack(["model"])
        return with_stack([None])

    if leaf == "w" and ndim == 2:
        if parent in _COL_KEYS:
            return with_stack(["data" if fsdp else None, "model"])
        if parent in _ROW_KEYS:
            return with_stack(["model", "data" if fsdp else None])
        if parent == "router":  # small, replicated
            return with_stack([None, None])
        # default 2D: col-parallel
        return with_stack(["data" if fsdp else None, "model"])

    # norms / scalars / small vectors: replicated
    return with_stack([None] * ndim)


def param_specs(params: Any, cfg: ModelConfig, *, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpecs matching ``params``."""
    def spec(path, leaf):
        keys = tuple(_path_key(p) for p in path)
        stacked = bool(keys) and (keys[0].startswith("seg") or keys[0] == "enc")
        s = _spec_for(keys, leaf.shape, stacked, fsdp)
        return _validate(s, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec, params)


def _path_key(p) -> str:
    return str(getattr(p, "key", getattr(p, "idx", p)))


def _validate(spec: P, shape: tuple[int, ...]) -> P:
    """Drop axis assignments that don't divide the dim (e.g. kv_heads=1 MQA
    projections smaller than the model axis, tiny vocab in smoke configs).
    XLA would replicate-with-padding; explicit None keeps the HLO clean."""
    # NOTE: divisibility depends on mesh axis sizes; checked at apply time
    return spec


def fit_specs(specs: Any, arrays: Any, mesh: Mesh) -> Any:
    """Drop axis assignments whose mesh size doesn't divide the dim (e.g.
    global_batch=1 on a 16-way data axis, MQA kv=1 head projections)."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec_leaf, arr):
        dims = list(spec_leaf) + [None] * (arr.ndim - len(spec_leaf))
        out = []
        for d, name in zip(arr.shape, dims):
            if name is None:
                out.append(None)
                continue
            size = (int(np.prod([axis_size[a] for a in name]))
                    if isinstance(name, tuple) else axis_size.get(name, 1))
            out.append(name if d % size == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(fix, specs, arrays,
                                  is_leaf=lambda x: isinstance(x, P))


def specs_with_mesh(params: Any, cfg: ModelConfig, mesh: Mesh, *,
                    fsdp: bool = False) -> Any:
    """param_specs + per-dim divisibility check against the actual mesh."""
    return fit_specs(param_specs(params, cfg, fsdp=fsdp), params, mesh)


# ---------------------------------------------------------------------------
# activation / batch specs
# ---------------------------------------------------------------------------

def batch_specs(batch_specs_tree: Any, mesh: Mesh) -> Any:
    """Shard the leading (batch) dim of every input over the data axes."""
    dp = data_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def spec(x):
        return P(dp, *([None] * (len(x.shape) - 1)))

    return jax.tree_util.tree_map(spec, batch_specs_tree)


def cache_specs(cache_tree: Any, mesh: Mesh) -> Any:
    """Decode caches: stacked (L, B, ...) KV/SSM buffers -> batch on data.

    Cache leaves are (layers, batch, ...) or scalars (pos/length)."""
    dp = data_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def spec(x):
        if len(x.shape) >= 2:
            return P(None, dp, *([None] * (len(x.shape) - 2)))
        return P()
    return jax.tree_util.tree_map(spec, cache_tree)


def shard_params(params: Any, specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)
