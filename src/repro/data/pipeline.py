"""Federated data pipeline: per-client shards + epoch batch iterators.

``FederatedData`` owns the full arrays and the Dirichlet partition;
``batch_iterator`` yields shuffled minibatches per local epoch (numpy on the
host — the arrays are small; device transfer happens inside the jitted step).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.dirichlet import dirichlet_partition, partition_stats


@dataclasses.dataclass
class ClientData:
    x: np.ndarray
    y: np.ndarray

    @property
    def n(self) -> int:
        return len(self.y)


@dataclasses.dataclass
class FederatedData:
    clients: list[ClientData]
    test_x: np.ndarray
    test_y: np.ndarray
    label_matrix: np.ndarray     # (K, C) counts, paper Fig.3

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    @property
    def total_n(self) -> int:
        return sum(c.n for c in self.clients)

    @classmethod
    def from_arrays(cls, x: np.ndarray, y: np.ndarray, test_x, test_y,
                    n_clients: int, alpha: float, seed: int = 0):
        parts = dirichlet_partition(y, n_clients, alpha, seed=seed)
        clients = [ClientData(x[idx], y[idx]) for idx in parts]
        return cls(clients, test_x, test_y, partition_stats(y, parts))


def batch_iterator(rng: np.random.Generator, data: ClientData, batch_size: int,
                   epochs: int = 1, drop_remainder: bool = False):
    """Yield (x, y) minibatches for ``epochs`` shuffled passes."""
    n = data.n
    bs = min(batch_size, n)
    for _ in range(epochs):
        order = rng.permutation(n)
        end = n - (n % bs) if drop_remainder else n
        for i in range(0, end, bs):
            idx = order[i:i + bs]
            if len(idx) < bs:  # pad final partial batch by wrapping
                idx = np.concatenate([idx, order[: bs - len(idx)]])
            yield data.x[idx], data.y[idx]


def num_batches(n: int, batch_size: int, epochs: int) -> int:
    bs = min(batch_size, n)
    return epochs * int(np.ceil(n / bs))
