"""Federated data pipeline: per-client shards + epoch batch iterators.

``FederatedData`` owns the full arrays and the Dirichlet partition;
``batch_iterator`` yields shuffled minibatches per local epoch (numpy on the
host — the arrays are small; device transfer happens inside the jitted step).

Device-resident slabs
---------------------
The multi-device executor (``repro.core.executor.ShardMapExecutor``) keeps
each client's FULL shard on the device that owns the client's slot of the
``("clients",)`` mesh, as a zero-padded "slab" whose row count is quantized
(``slab_rows``) so shapes stay stable as cohorts change.  ``ClientSlabStore``
owns those slabs, keyed by the stable client id: a client sampled in
consecutive rounds re-uses its resident slab — per-round host→device traffic
drops to the sampled cohort's batch-pick indices and masks.  The store counts
``host_transfers`` (numpy → device uploads), ``device_moves`` (a cached slab
re-pinned because the client landed on a different mesh slot) and ``hits``
so tests and telemetry can assert residency instead of guessing.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from repro.data.dirichlet import dirichlet_partition, partition_stats

SLAB_QUANT = 64   # slab rows are multiples of this (stable shapes => stable
                  # compiled executables as ragged cohorts rotate)


@dataclasses.dataclass
class ClientData:
    x: np.ndarray
    y: np.ndarray

    @property
    def n(self) -> int:
        return len(self.y)


@dataclasses.dataclass
class FederatedData:
    clients: list[ClientData]
    test_x: np.ndarray
    test_y: np.ndarray
    label_matrix: np.ndarray     # (K, C) counts, paper Fig.3

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    @property
    def total_n(self) -> int:
        return sum(c.n for c in self.clients)

    @classmethod
    def from_arrays(cls, x: np.ndarray, y: np.ndarray, test_x, test_y,
                    n_clients: int, alpha: float, seed: int = 0):
        parts = dirichlet_partition(y, n_clients, alpha, seed=seed)
        clients = [ClientData(x[idx], y[idx]) for idx in parts]
        return cls(clients, test_x, test_y, partition_stats(y, parts))

    def client_n(self, cid: int) -> int:
        return self.clients[int(cid)].n

    def max_client_n(self) -> int:
        """Largest client — the shape bound fixed-slot async waves pin
        their one compiled round body to."""
        return int(max(c.n for c in self.clients))

    def sample_cohort(self, rng: np.random.Generator, k: int,
                      exclude=None) -> np.ndarray:
        """Flat uniform-without-replacement cohort draw (the historical
        sampling): one ``rng.choice`` over the whole population, or over
        the sorted non-``exclude`` ids for the async loop's refills.  The
        population tier (``repro.population``) overrides this with the
        hierarchical O(cohort) draw; at ``n_shards=1`` that draw consumes
        the generator identically to THESE calls — keep them in sync."""
        if not exclude:
            return rng.choice(self.n_clients, size=k, replace=False)
        idle = np.setdiff1d(np.arange(self.n_clients, dtype=np.int64),
                            np.fromiter(exclude, np.int64))
        return idle[rng.choice(len(idle), size=k, replace=False)]


def batch_iterator(rng: np.random.Generator, data: ClientData, batch_size: int,
                   epochs: int = 1, drop_remainder: bool = False):
    """Yield (x, y) minibatches for ``epochs`` shuffled passes."""
    n = data.n
    bs = min(batch_size, n)
    for _ in range(epochs):
        order = rng.permutation(n)
        end = n - (n % bs) if drop_remainder else n
        for i in range(0, end, bs):
            idx = order[i:i + bs]
            if len(idx) < bs:  # pad final partial batch by wrapping
                idx = np.concatenate([idx, order[: bs - len(idx)]])
            yield data.x[idx], data.y[idx]


def num_batches(n: int, batch_size: int, epochs: int) -> int:
    bs = min(batch_size, n)
    return epochs * int(np.ceil(n / bs))


# ---------------------------------------------------------------------------
# device-resident slab layout (the ShardMapExecutor placement layer)
# ---------------------------------------------------------------------------

def slab_rows(n: int) -> int:
    """Quantized slab row count: ``n`` rounded up to SLAB_QUANT."""
    return max(SLAB_QUANT, int(-(-n // SLAB_QUANT)) * SLAB_QUANT)


def make_slab(data: ClientData, rows: int) -> tuple[np.ndarray, np.ndarray]:
    """Zero-pad one client's shard to ``rows`` (labels as int32).

    Padded rows carry arbitrary (zero) values — every consumer sees them
    through a validity mask or through batch-pick gathers that an example
    mask zero-weights, so the pad never reaches a loss.
    """
    assert rows >= data.n, (rows, data.n)
    x = np.zeros((rows,) + data.x.shape[1:], data.x.dtype)
    y = np.zeros((rows,), np.int32)
    x[:data.n] = data.x
    y[:data.n] = data.y
    return x, y


class ClientSlabStore:
    """Device-resident per-client shard slabs, keyed by stable client id.

    ``get(cid, data, device)`` returns ``{"x", "y", "n", "rows", "device"}``
    with ``x``/``y`` committed to ``device``.  Repeat lookups for a resident
    client are cache hits (no host transfer); a client whose mesh slot
    changed is moved device-to-device, never re-uploaded from the host.
    ``cid=None`` disables caching (every call is a fresh upload).

    ``max_resident`` bounds device memory under partial participation —
    without it every client ever sampled would stay pinned forever.  The
    store evicts least-recently-USED clients past the cap (an evicted
    client re-uploads from the host on its next sample); ``None`` means
    unbounded, the right default for full-participation runs and the
    equivalence suites.

    The population tier (``repro.population``) couples to the store three
    ways: ``drop(cid)`` invalidates a slab when the client leaves the
    warm host tier (counted separately from cap evictions), ``on_evict``
    observes cap evictions so cross-tier telemetry stays truthful, and
    ids in ``pinned`` (shared by reference with the population store) are
    never cap-evicted — the async loop's in-flight clients keep their
    slabs however many waves dispatch before their completions aggregate.
    With more pinned clients than ``max_resident`` the store temporarily
    exceeds the cap rather than evict pinned work.

    Under multi-host placement (``repro.population.placement``) each
    host's devices own only a shard subset; set ``owns`` to that
    membership predicate and the store REFUSES to materialize a slab for
    an unowned client — a placement bug surfaces as a loud ValueError
    here instead of silently doubling per-host device memory.
    """

    def __init__(self, max_resident: Optional[int] = None,
                 on_evict=None, owns=None):
        self.slabs: "collections.OrderedDict" = collections.OrderedDict()
        self.max_resident = max_resident
        self.on_evict = on_evict        # called (cid, entry) on cap eviction
        self.owns = owns                # optional cid -> bool ownership gate
        self.pinned: set = set()        # exempt from cap eviction
        self.host_transfers = 0
        self.device_moves = 0
        self.hits = 0
        self.evictions = 0
        self.drops = 0                  # explicit drop(cid) invalidations
        # high-water mark of resident slabs: under churning async cohorts
        # this is the device-memory bound the cap actually enforced
        self.peak_resident = 0

    def get(self, cid, data: ClientData, device) -> dict:
        import jax

        if self.owns is not None and cid is not None and not self.owns(cid):
            raise ValueError(
                f"slab store: client {cid} is not owned by this host's "
                f"placement — the multi-host round must slice the cohort "
                f"to owned clients before materializing")
        entry = self.slabs.get(cid) if cid is not None else None
        if entry is not None and entry["n"] == data.n:
            self.slabs.move_to_end(cid)
            if entry["device"] == device:
                self.hits += 1
                return entry
            entry = dict(entry, device=device,
                         x=jax.device_put(entry["x"], device),
                         y=jax.device_put(entry["y"], device))
            self.slabs[cid] = entry
            self.device_moves += 1
            return entry
        rows = slab_rows(data.n)
        x, y = make_slab(data, rows)
        entry = {"device": device, "x": jax.device_put(x, device),
                 "y": jax.device_put(y, device), "n": data.n, "rows": rows}
        if cid is not None:
            self.slabs[cid] = entry
            self.slabs.move_to_end(cid)
            while (self.max_resident is not None
                   and len(self.slabs) > self.max_resident):
                victim = next((k for k in self.slabs
                               if k not in self.pinned), None)
                if victim is None:      # everything pinned: exceed the cap
                    break
                evicted = self.slabs.pop(victim)
                self.evictions += 1
                if self.on_evict is not None:
                    self.on_evict(victim, evicted)
            self.peak_resident = max(self.peak_resident, len(self.slabs))
        self.host_transfers += 1
        return entry

    def drop(self, cid) -> bool:
        """Invalidate ``cid``'s slab (the client left the warm host tier,
        or its shard was rewritten).  Not an LRU eviction: counted in
        ``drops``, never in ``evictions``, and ``on_evict`` does not fire
        — the caller initiated it and needs no write-back signal.  The
        client re-uploads from the host on its next sample."""
        if self.slabs.pop(cid, None) is None:
            return False
        self.drops += 1
        return True

    def stats(self) -> dict:
        return {"resident_clients": len(self.slabs),
                "host_transfers": self.host_transfers,
                "device_moves": self.device_moves, "hits": self.hits,
                "evictions": self.evictions, "drops": self.drops,
                "peak_resident": self.peak_resident}
