"""Dirichlet non-IID client partitioning (Hsu et al. 2019), as in the paper.

For each class c, a Dir(α) draw over the K clients decides what fraction of
class-c examples each client receives.  Small α → highly skewed label
distributions (the paper sweeps α ∈ {1, 0.5, 0.1}).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 2) -> list[np.ndarray]:
    """Return per-client index arrays (disjoint cover of ``labels``)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            client_idx[k].extend(part.tolist())
    # guarantee a minimum per client (move from the largest)
    sizes = [len(ci) for ci in client_idx]
    order = np.argsort(sizes)
    for k in order:
        while len(client_idx[k]) < min_per_client:
            donor = int(np.argmax([len(ci) for ci in client_idx]))
            client_idx[k].append(client_idx[donor].pop())
    out = [np.array(sorted(ci), dtype=np.int64) for ci in client_idx]
    assert sum(len(o) for o in out) == len(labels)
    return out


def partition_stats(labels: np.ndarray, parts: list[np.ndarray]) -> np.ndarray:
    """(K, C) label-count matrix — the paper's Fig.3 visualization data."""
    n_classes = int(labels.max()) + 1
    mat = np.zeros((len(parts), n_classes), dtype=np.int64)
    for k, idx in enumerate(parts):
        cls, cnt = np.unique(labels[idx], return_counts=True)
        mat[k, cls] = cnt
    return mat
