"""Synthetic dataset generators (offline stand-ins for CIFAR/AG-News/SST-5).

Design goals: (1) deterministic given a seed; (2) genuinely learnable but not
trivially so (class structure + heavy noise + nuisance factors) so that
method-vs-method *orderings* (FedGKD vs FedAvg vs FedProx ...) are
meaningful; (3) same label cardinalities as the paper's datasets.

Images: each class has a low-frequency template (random Fourier features);
samples = template · random per-sample contrast + Gaussian noise + random
shift — a crude CIFAR-like manifold.
Text: each class has a token-unigram tilt over a shared Zipfian base; a
sample is a token sequence drawn from the mixed distribution with a few
class-indicative "keyword" tokens inserted at random positions.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticImageTask:
    num_classes: int
    hw: int = 32
    channels: int = 3
    noise: float = 0.8
    seed: int = 0

    def generate(self, n: int, seed: int | None = None):
        rng = np.random.default_rng(self.seed if seed is None else seed)
        c, hwd = self.num_classes, self.hw
        # low-frequency class templates
        yy, xx = np.meshgrid(np.linspace(0, 1, hwd), np.linspace(0, 1, hwd),
                             indexing="ij")
        templates = np.zeros((c, hwd, hwd, self.channels), np.float32)
        for k in range(c):
            for ch in range(self.channels):
                for _ in range(3):
                    fx, fy = rng.uniform(0.5, 3.0, 2)
                    ph = rng.uniform(0, 2 * np.pi)
                    templates[k, :, :, ch] += np.sin(
                        2 * np.pi * (fx * xx + fy * yy) + ph)
        templates /= np.sqrt((templates ** 2).mean((1, 2, 3), keepdims=True) + 1e-8)

        # shift-invariant per-class channel bias (keeps the task learnable
        # under the circular-shift nuisance below)
        chan_bias = rng.normal(0, 0.5, size=(c, 1, 1, self.channels)).astype(
            np.float32)

        labels = rng.integers(0, c, size=n)
        contrast = rng.uniform(0.6, 1.4, size=(n, 1, 1, 1)).astype(np.float32)
        x = templates[labels] * contrast
        # random circular shifts (nuisance)
        sh = rng.integers(-2, 3, size=(n, 2))
        for i in range(n):
            x[i] = np.roll(x[i], tuple(sh[i]), axis=(0, 1))
        x += chan_bias[labels]
        x += rng.normal(0, self.noise, x.shape).astype(np.float32)
        return x.astype(np.float32), labels.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SyntheticTextTask:
    num_classes: int
    vocab_size: int = 2000
    seq_len: int = 64
    n_keywords: int = 12     # class-indicative tokens per class
    keyword_rate: float = 0.12
    seed: int = 0

    def generate(self, n: int, seed: int | None = None):
        rng = np.random.default_rng(self.seed if seed is None else seed)
        v, c, s = self.vocab_size, self.num_classes, self.seq_len
        base = 1.0 / (np.arange(v) + 10.0)   # Zipfian background
        base /= base.sum()
        keywords = rng.choice(np.arange(16, v), size=(c, self.n_keywords),
                              replace=False if c * self.n_keywords <= v - 16 else True)
        labels = rng.integers(0, c, size=n)
        toks = rng.choice(v, size=(n, s), p=base)
        kw_mask = rng.random((n, s)) < self.keyword_rate
        kw_pick = keywords[labels][np.arange(n)[:, None],
                                   rng.integers(0, self.n_keywords, (n, s))]
        toks = np.where(kw_mask, kw_pick, toks)
        return toks.astype(np.int32), labels.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SyntheticTabularTask:
    """Gaussian class blobs under a shared random rotation — the light
    MLP workload used by the executor benchmarks and quick examples."""
    num_classes: int
    dim: int = 16
    noise: float = 1.0
    seed: int = 0

    def generate(self, n: int, seed: int | None = None):
        rng = np.random.default_rng(self.seed if seed is None else seed)
        # class means fixed by a task-level rng so train/test share them
        mrng = np.random.default_rng(self.seed + 77)
        means = mrng.normal(0, 1, size=(self.num_classes, self.dim))
        means *= 2.0 / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-9)
        rot, _ = np.linalg.qr(mrng.normal(0, 1, (self.dim, self.dim)))
        labels = rng.integers(0, self.num_classes, size=n)
        x = means[labels] + rng.normal(0, self.noise, (n, self.dim))
        return (x @ rot).astype(np.float32), labels.astype(np.int64)


def make_task_data(task, n_train: int, n_test: int, seed: int = 0):
    """Generate (train_x, train_y, test_x, test_y) for a PaperTask-like obj."""
    from repro.configs.paper import PaperTask  # local import, avoids cycle
    assert isinstance(task, PaperTask)
    if task.kind == "image":
        gen = SyntheticImageTask(task.num_classes, hw=task.image_hw, seed=seed)
    elif task.kind == "tabular":
        gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim,
                                   seed=seed)
    else:
        gen = SyntheticTextTask(task.num_classes, vocab_size=task.vocab_size,
                                seq_len=task.seq_len, seed=seed)
    xtr, ytr = gen.generate(n_train, seed=seed)
    xte, yte = gen.generate(n_test, seed=seed + 10_000)
    return xtr, ytr, xte, yte


def lm_token_batches(rng: np.random.Generator, batch: int, seq: int,
                     vocab: int) -> np.ndarray:
    """Markov-chain token stream for LM-style training examples."""
    # sparse random transition structure, shared bigram backbone
    state = rng.integers(0, vocab, size=batch)
    stride = max(1, vocab // 17)
    out = np.empty((batch, seq), np.int32)
    for t in range(seq):
        jump = rng.random(batch) < 0.15
        nxt = np.where(jump, rng.integers(0, vocab, batch),
                       (state * 31 + 7) % max(1, vocab - stride) + rng.integers(0, stride, batch))
        out[:, t] = nxt
        state = nxt
    return out
