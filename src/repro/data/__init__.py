from repro.data.dirichlet import dirichlet_partition, partition_stats  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    SyntheticImageTask, SyntheticTextTask, make_task_data, lm_token_batches,
)
from repro.data.pipeline import ClientData, FederatedData, batch_iterator  # noqa: F401
