"""All federated algorithms from the paper's evaluation (Tab. 1 / §5.1).

    fedavg        McMahan et al. 2017 — plain weighted averaging
    fedprox       Li et al. 2018 — + (μ/2)‖w − w_t‖² proximal term
    moon          Li et al. 2021 — model-contrastive loss (projection head)
    feddistill+   Seo et al. 2020 (+ param sharing) — per-label global logits
    fedgen        Zhu et al. 2021 — server-side feature generator
    fedgkd        THE PAPER — fused historical-global-ensemble teacher, Eq. 4
    fedgkd-vote   Eq. 5 — M teachers with validation-softmax coefficients
    fedgkd+       fedgkd on the projection-head model (vs MOON)

Every algorithm implements the same small interface; the FL loop
(repro.core.fl_loop) is algorithm-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distillation as D
from repro.core.modelzoo import ModelBundle
from repro.core.server import ModelBuffer, first_nonfinite_path, \
    weighted_average
from repro.models import layers


class Algorithm:
    """Base: FedAvg behaviour; subclasses override the regularizer hooks.

    Executor contract (see ``repro.core.executor``): ``loss_fn``,
    ``client_finalize`` and ``update_client_state`` must be pure
    pytree-in/pytree-out with no Python-side per-client branching, so a
    ``ClientExecutor`` may trace them once and vmap/shard them over a
    stacked client axis.  ``mask`` is a per-example weight vector (padded
    examples carry weight 0); ``mask=None`` means all-ones.
    """

    name = "fedavg"
    needs_projection_head = False
    comm_multiplier = 1.0     # download cost relative to FedAvg
    supports_vmap = True      # set False to force sequential execution

    def __init__(self, **kw):
        self.hp = kw

    # -- server ------------------------------------------------------------
    def init_server(self, global_params: Any, model: ModelBundle,
                    num_classes: int) -> dict:
        return {"global": global_params, "round": 0}

    def round_payload(self, server: dict, rng: jax.Array) -> Any:
        """Broadcast content beyond the global weights (fixed pytree struct)."""
        return ()

    def server_update(self, server: dict, uploads: list[dict],
                      weights: list[float], model: ModelBundle,
                      val_batch=None, n_clients: int | None = None) -> dict:
        """Aggregate the round.  ``n_clients`` is the TOTAL client count K
        (|uploads| is only the sampled cohort |S|); algorithms whose update
        scales with the participation fraction |S|/K need it."""
        new_global = weighted_average([u["params"] for u in uploads], weights)
        server = dict(server)
        server["global"] = new_global
        server["round"] += 1
        return server

    # -- client ------------------------------------------------------------
    def init_client_state(self, client_id: int, global_params: Any) -> Any:
        return ()

    def precompute_aux(self, model: ModelBundle, payload: Any, x: Any,
                       y: Any, mask: Any) -> Any:
        """Round-constant per-example tensors (see ``repro.core.executor``).

        Called by executors ONCE per round on each client's full shard,
        outside autodiff — anything the loss needs that depends only on
        (payload, data) belongs here, not inside the differentiated step.
        ``None`` (the default) means the algorithm has no precompute stage;
        otherwise return a pytree of arrays with leading axis ``len(x)``
        that executors gather per batch and feed to ``loss_fn`` as ``aux``.
        """
        return None

    def precompute_parts(self, payload: Any):
        """Optional incremental decomposition of ``precompute_aux``.

        ``None`` (default), or ``(keys, get_part)``: ``keys[m]`` is a stable
        hashable version id of part ``m``'s payload slice (UNCHANGED parts
        must keep their key across rounds) and ``get_part(m)`` returns that
        slice.  Executors then cache each part's per-example output — from
        ``precompute_part`` — under ``(client_id, key)`` across rounds and
        recompute only parts whose key is new, folding the stacked outputs
        with ``precompute_combine``.  FedGKD-VOTE uses this: a round
        replaces ONE of the M buffered teachers, so steady-state teacher
        inference drops from M to 1 forward per shard per round.
        """
        return None

    def precompute_part(self, model: ModelBundle, part_payload: Any,
                        x: Any) -> jax.Array:
        """Per-example output of ONE cacheable part: (N, ...) array."""
        raise NotImplementedError

    def precompute_combine(self, payload: Any, parts: jax.Array, x: Any,
                           y: Any, mask: Any) -> Any:
        """Fold stacked part outputs (n_parts, N, ...) into the aux pytree.
        Must equal ``precompute_aux`` run directly on the same shard."""
        raise NotImplementedError

    def loss_fn(self, model: ModelBundle):
        """Return loss(params, payload, client_state, x, y, mask=None,
        aux=None) -> (loss, metrics).  ``aux`` carries the per-batch rows of
        ``precompute_aux`` (or None when executed without precompute)."""

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            return D.cross_entropy(logits, y, mask=mask), {}

        return loss

    def batched_loss_fn(self, model: ModelBundle):
        """Client-STACKED form of ``loss_fn`` for client-batched models.

        Returns ``loss(params, payload, client_states, x, y, mask, aux) ->
        (total, per_client)`` where every leaf carries a leading cohort
        axis — params ``(K, ...)``, x ``(K, B, ...)`` — and ``per_client[k]``
        equals ``loss_fn``'s scalar for client k.  ``total`` is their sum:
        client parameters are disjoint, so one ``value_and_grad`` of the
        sum yields exactly the per-client gradients WITHOUT vmapping the
        model — the model's apply consumes the stacked pytree natively
        (conv backbones route through ``kernels.grouped_conv``).  Used by
        the batched executors when ``ModelBundle.client_batched`` is set;
        ``None`` (returned whenever a subclass overrides ``loss_fn``
        without providing a stacked form) falls back to the vmapped path.
        """
        if type(self).loss_fn is not Algorithm.loss_fn:
            return None

        def loss(params, payload, client_states, x, y, mask, aux=None):
            per = D.cross_entropy_per_client(model.apply(params, x), y,
                                             mask=mask)
            return jnp.sum(per), per

        return loss

    def absorb_stale(self, server: dict, uploads: list[dict],
                     staleness: list[float], weights: list[float],
                     model: ModelBundle | None = None,
                     val_batch=None) -> dict:
        """Async-aggregation hook: what to do with STALE arrivals beyond
        (down-)weighting them in the parameter average.

        Called by the buffered async server (``fl_loop``,
        ``executor="async"`` under the ``"fedgkd"`` staleness scheme) after
        ``server_update``, with the full aggregation buffer, each update's
        staleness, and each update's DATA weight n_k (never the scaled
        aggregation weight — past-cutoff updates scale to zero there,
        which is exactly when this hook matters).  The base discards —
        KD algorithms override to absorb stale models into the historical
        teacher buffer, where drift-regularization wants them (stale
        clients still distill toward recent global knowledge).
        """
        return server

    def client_finalize(self, model: ModelBundle, params: Any,
                        x: Any, y: Any, mask: Any, payload: Any) -> dict:
        """Extra uploads beyond the trained weights.

        ``x``/``y`` are the client's (possibly padded) full arrays and
        ``mask`` the per-example validity weights — pure jnp only, so the
        hook can be vmapped over stacked clients.
        """
        return {}

    def update_client_state(self, client_state: Any, params: Any,
                            payload: Any = None) -> Any:
        return client_state


# ---------------------------------------------------------------------------

class FedProx(Algorithm):
    name = "fedprox"

    def __init__(self, mu: float = 0.01, **kw):
        super().__init__(mu=mu, **kw)
        self.mu = mu

    def round_payload(self, server, rng):
        return {"anchor": server["global"]}

    def loss_fn(self, model):
        mu = self.mu

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            prox = 0.5 * mu * D.param_sq_dist(params, payload["anchor"])
            return D.cross_entropy(logits, y, mask=mask) + prox, {}

        return loss

    def batched_loss_fn(self, model):
        if type(self).loss_fn is not FedProx.loss_fn:
            return None          # subclass changed the objective
        mu = self.mu

        def loss(params, payload, client_states, x, y, mask, aux=None):
            per = D.cross_entropy_per_client(model.apply(params, x), y,
                                             mask=mask)
            per = per + 0.5 * mu * D.param_sq_dist_per_client(
                params, payload["anchor"])
            return jnp.sum(per), per

        return loss


# ---------------------------------------------------------------------------

class FedGKD(Algorithm):
    """The paper's method (Eq. 4): teacher = mean of the last M globals."""

    name = "fedgkd"

    def __init__(self, gamma: float = 0.2, buffer_m: int = 5,
                 loss_type: str = "kl", temperature: float = 1.0, **kw):
        super().__init__(gamma=gamma, buffer_m=buffer_m, loss_type=loss_type, **kw)
        self.gamma, self.buffer_m = gamma, buffer_m
        self.loss_type, self.temperature = loss_type, temperature

    @property
    def comm_multiplier(self):
        return 2.0 if self.buffer_m > 1 else 1.0

    def init_server(self, global_params, model, num_classes):
        buf = ModelBuffer(self.buffer_m)
        buf.push(global_params)
        return {"global": global_params, "round": 0, "buffer": buf}

    def round_payload(self, server, rng):
        return {"teacher": server["buffer"].fused()}

    def precompute_aux(self, model, payload, x, y, mask):
        # The teacher is frozen for the whole round (Eq. 4): its logits are
        # constant per example, so one inference forward over the shard
        # replaces E·S teacher applies inside the differentiated scan.
        del y, mask
        return {"t_logits": model.apply(payload["teacher"], x)
                .astype(jnp.float32)}

    def loss_fn(self, model):
        gamma, ltype, temp = self.gamma, self.loss_type, self.temperature

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            t_logits = jax.lax.stop_gradient(
                aux["t_logits"] if aux is not None
                else model.apply(payload["teacher"], x))
            ce = D.cross_entropy(logits, y, mask=mask)
            if ltype == "mse":
                kd = D.kd_loss_mse(t_logits, logits, gamma, mask=mask)
            else:
                kd = D.kd_loss_kl(t_logits, logits, gamma, temp, mask=mask)
            return ce + kd, {"kd": kd}

        return loss

    def batched_loss_fn(self, model):
        if type(self).loss_fn is not FedGKD.loss_fn:
            return None          # subclass changed the objective
        gamma, ltype, temp = self.gamma, self.loss_type, self.temperature

        def loss(params, payload, client_states, x, y, mask, aux=None):
            logits = model.apply(params, x)                   # (K, B, C)
            if aux is not None:
                t_logits = aux["t_logits"]
            else:
                # the teacher is ONE shared model: fold the cohort into the
                # batch axis for a plain single-model forward (no stacked
                # weights, no vmap) and unfold the logits
                k, b = x.shape[0], x.shape[1]
                t_logits = model.apply(
                    payload["teacher"],
                    x.reshape((k * b,) + x.shape[2:])).reshape(k, b, -1)
            t_logits = jax.lax.stop_gradient(t_logits)
            per = D.cross_entropy_per_client(logits, y, mask=mask)
            if ltype == "mse":
                d = (t_logits.astype(jnp.float32)
                     - logits.astype(jnp.float32))
                kd = 0.5 * gamma * D.masked_mean_per_client(
                    jnp.sum(jnp.square(d), axis=-1), mask)
            else:
                kd = 0.5 * gamma * D.masked_mean_per_client(
                    D.kl_divergence(t_logits, logits, temp), mask)
            per = per + kd
            return jnp.sum(per), per

        return loss

    def server_update(self, server, uploads, weights, model, val_batch=None,
                      n_clients=None):
        server = super().server_update(server, uploads, weights, model,
                                       val_batch, n_clients)
        server["buffer"].push(server["global"])
        return server

    def absorb_stale(self, server, uploads, staleness, weights, model=None,
                     val_batch=None):
        """Late arrivals join the historical-teacher ensemble (Eq. 4's
        buffer) instead of being discarded: the stale client models are
        fused by their data weights into ONE buffer entry per aggregation
        event, so the ``ModelBuffer`` version counter bumps exactly once
        and the executor part-caches invalidate exactly one part.

        Quarantine: a non-finite stale model never becomes a teacher —
        the fault-handling loop validates updates before they get here,
        but ``absorb_stale`` is also reachable with raw buffer contents,
        and one poisoned entry would distill NaNs into every subsequent
        local step.  Invalid entries are skipped (no version bump, part
        caches stay clean); so is a fused result that is bitwise equal
        to the current head (``ModelBuffer.push`` refuses duplicates)."""
        stale = [(u["params"], w) for u, s, w in
                 zip(uploads, staleness, weights) if s > 0]
        stale = [(p, w) for p, w in stale
                 if first_nonfinite_path(p) is None]
        if not stale:
            return server
        fused = weighted_average([p for p, _ in stale],
                                 [w for _, w in stale])
        server["buffer"].push(fused)
        return server


class FedGKDPlus(FedGKD):
    """FedGKD on the projection-head model (the paper's MOON comparison)."""

    name = "fedgkd+"
    needs_projection_head = True


# ---------------------------------------------------------------------------

class FedGKDVote(FedGKD):
    """Eq. 5: all M buffered teachers, γ_m from validation-loss softmax.

    Payload stacks the M teachers on a leading axis (fixed pytree structure;
    early rounds pad with the newest model at γ=0).
    """

    name = "fedgkd-vote"

    def __init__(self, gamma: float = 0.2, buffer_m: int = 5, lam: float = 0.1,
                 **kw):
        super().__init__(gamma=gamma, buffer_m=buffer_m, **kw)
        self.lam = lam

    @property
    def comm_multiplier(self):
        return float(self.buffer_m)

    def init_server(self, global_params, model, num_classes):
        s = super().init_server(global_params, model, num_classes)
        s["val_losses"] = [0.0]
        return s

    def round_payload(self, server, rng):
        models = server["buffer"].models            # newest first, len m<=M
        versions = server["buffer"].versions
        m_avail = len(models)
        losses = server["val_losses"][:m_avail]
        gammas = D.vote_coefficients(losses, lam=self.lam)
        pad = self.buffer_m - m_avail
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(list(xs) + [xs[0]] * pad), *models)
        gvec = jnp.asarray(gammas + [0.0] * pad, jnp.float32)
        # versions pad with the NEWEST id, mirroring the teacher padding —
        # a padded slot is the same model, so its cached logits are too
        vvec = np.asarray(versions + [versions[0]] * pad, np.int32)
        return {"teachers": stacked, "gammas": gvec, "teacher_versions": vvec}

    def precompute_aux(self, model, payload, x, y, mask):
        """Collapse the M-teacher ensemble to per-example sufficient stats.

        Σ_m γ_m·KL(p_m‖p_s) = Σ_m γ_m Σ_c p_mc·log p_mc
                              − Σ_c (Σ_m γ_m p_mc)·log p_sc
        so the loss only needs the γ-mixture ``tbar`` (C-vector) and the
        γ-weighted negative entropy ``tent`` (scalar) per example — the
        per-step ``lax.map`` over M stacked teacher models disappears.
        """
        # vmap (not lax.map): M batched-weight matmuls beat a sequential
        # M-iteration loop — this runs once per round, off the autodiff path
        t_logits = jax.vmap(
            lambda t: self.precompute_part(model, t, x))(payload["teachers"])
        return self.precompute_combine(payload, t_logits, x, y, mask)

    def precompute_parts(self, payload):
        versions = payload.get("teacher_versions")
        if versions is None:
            return None
        keys = tuple(int(v) for v in np.asarray(versions))
        get_part = lambda m: jax.tree_util.tree_map(
            lambda l: l[m], payload["teachers"])
        return keys, get_part

    def precompute_part(self, model, part_payload, x):
        return model.apply(part_payload, x).astype(jnp.float32)   # (N, C)

    def precompute_combine(self, payload, parts, x, y, mask):
        del x, y, mask
        temp = self.temperature
        logp = jax.nn.log_softmax(parts.astype(jnp.float32) / temp, axis=-1)
        p = jnp.exp(logp)
        g = payload["gammas"].astype(jnp.float32)             # (M,)
        return {"tbar": jnp.einsum("m,mnc->nc", g, p),
                "tent": jnp.einsum("m,mnc->n", g, p * logp)}

    def loss_fn(self, model):
        temp = self.temperature

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            ce = D.cross_entropy(logits, y, mask=mask)

            if aux is not None:
                logp_s = jax.nn.log_softmax(
                    logits.astype(jnp.float32) / temp, axis=-1)
                kls = (aux["tent"] - jnp.sum(aux["tbar"] * logp_s, axis=-1)
                       ) * (temp * temp)                      # Σ_m γ_m·KL_m
                kd = 0.5 * D.masked_mean(kls, mask)
            else:
                def one(teacher):
                    t_logits = model.apply(teacher, x)
                    return D.masked_mean(
                        D.kl_divergence(t_logits, logits, temp), mask)

                kls = jax.lax.map(one, payload["teachers"])   # (M,)
                kd = 0.5 * jnp.sum(payload["gammas"] * kls)   # Σ (γ_m/2)·KL_m
            return ce + kd, {"kd": kd}

        return loss

    def batched_loss_fn(self, model):
        if type(self).loss_fn is not FedGKDVote.loss_fn:
            return None          # subclass changed the objective
        temp = self.temperature

        def loss(params, payload, client_states, x, y, mask, aux=None):
            logits = model.apply(params, x)                   # (K, B, C)
            per = D.cross_entropy_per_client(logits, y, mask=mask)
            if aux is not None:
                logp_s = jax.nn.log_softmax(
                    logits.astype(jnp.float32) / temp, axis=-1)
                kls = (aux["tent"] - jnp.sum(aux["tbar"] * logp_s, axis=-1)
                       ) * (temp * temp)                      # (K, B)
                kd = 0.5 * D.masked_mean_per_client(kls, mask)
            else:
                k, b = x.shape[0], x.shape[1]
                xf = x.reshape((k * b,) + x.shape[2:])

                def one(teacher):                             # shared model:
                    t = model.apply(teacher, xf).reshape(k, b, -1)
                    return D.masked_mean_per_client(
                        D.kl_divergence(t, logits, temp), mask)

                kls = jax.lax.map(one, payload["teachers"])   # (M, K)
                kd = 0.5 * jnp.sum(payload["gammas"][:, None] * kls, axis=0)
            per = per + kd
            return jnp.sum(per), per

        return loss

    def server_update(self, server, uploads, weights, model, val_batch=None,
                      n_clients=None):
        server = super().server_update(server, uploads, weights, model,
                                       val_batch, n_clients)
        self._refresh_val_losses(server, model, val_batch)
        return server

    def absorb_stale(self, server, uploads, staleness, weights, model=None,
                     val_batch=None):
        """A stale-fused buffer entry needs a vote coefficient too: after
        the FedGKD ingestion the val-loss list is recomputed so γ covers
        the absorbed teacher (without a val batch it pads pessimistically
        with the worst current loss, giving the stale entry the smallest
        vote rather than a free ride)."""
        # detect the push by version, not length: a full deque keeps its
        # length on push (oldest entry evicted)
        newest = server["buffer"].versions[0]
        server = super().absorb_stale(server, uploads, staleness, weights,
                                      model, val_batch)
        if server["buffer"].versions[0] == newest:
            return server           # nothing was stale, nothing was pushed
        if model is not None and val_batch is not None:
            self._refresh_val_losses(server, model, val_batch)
        else:
            # newest-first like buffer.models: the absorbed entry is the
            # newest, priced at the worst current loss
            worst = max(server["val_losses"], default=0.0)
            server["val_losses"] = (
                [worst] + list(server["val_losses"]))[:len(server["buffer"])]
        return server

    def _refresh_val_losses(self, server, model, val_batch):
        # validation loss per buffered model (paper: γ set by val performance)
        if val_batch is not None:
            vx, vy = val_batch
            losses = []
            for p in server["buffer"].models:
                logits = model.apply(p, vx)
                losses.append(float(D.cross_entropy(logits, vy)))
            server["val_losses"] = losses
        else:
            server["val_losses"] = [0.0] * len(server["buffer"])


# ---------------------------------------------------------------------------

class MOON(Algorithm):
    """Model-contrastive FL: positive = global features, negative = the
    client's previous local model features (projection head, τ=0.5)."""

    name = "moon"
    needs_projection_head = True

    def __init__(self, mu: float = 5.0, tau: float = 0.5, **kw):
        super().__init__(mu=mu, tau=tau, **kw)
        self.mu, self.tau = mu, tau

    def round_payload(self, server, rng):
        return {"global": server["global"]}

    def init_client_state(self, client_id, global_params):
        return {"prev": global_params}

    def loss_fn(self, model):
        mu, tau = self.mu, self.tau

        def cos(a, b):
            # eps inside the rsqrt: keeps the gradient finite for an
            # exactly-zero feature row (a padded example under vmap)
            a = a * jax.lax.rsqrt(jnp.sum(a * a, -1, keepdims=True) + 1e-12)
            b = b * jax.lax.rsqrt(jnp.sum(b * b, -1, keepdims=True) + 1e-12)
            return jnp.sum(a * b, axis=-1)

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            z = model.features(params, x)
            z_g = jax.lax.stop_gradient(model.features(payload["global"], x))
            z_p = jax.lax.stop_gradient(model.features(client_state["prev"], x))
            pos = jnp.exp(cos(z, z_g) / tau)
            neg = jnp.exp(cos(z, z_p) / tau)
            con = -D.masked_mean(jnp.log(pos / (pos + neg) + 1e-12), mask)
            return D.cross_entropy(logits, y, mask=mask) + mu * con, {"con": con}

        return loss

    def update_client_state(self, client_state, params, payload=None):
        return {"prev": params}


# ---------------------------------------------------------------------------

class FedDistillPlus(Algorithm):
    """FedDistill (per-label averaged logits shared) + parameter sharing.

    Clients upload their per-class mean logits; the server averages them into
    a global (C, C) table used as the per-label teacher next round.
    """

    name = "feddistill+"

    def __init__(self, beta: float = 0.1, temperature: float = 1.0, **kw):
        super().__init__(beta=beta, **kw)
        self.beta, self.temperature = beta, temperature

    def init_server(self, global_params, model, num_classes):
        return {"global": global_params, "round": 0,
                "label_logits": jnp.zeros((num_classes, num_classes), jnp.float32),
                "have_logits": jnp.zeros((), jnp.float32)}

    def round_payload(self, server, rng):
        return {"label_logits": server["label_logits"],
                "enable": server["have_logits"]}

    def precompute_aux(self, model, payload, x, y, mask):
        # label-table gather is round-constant per example: hoisting it out
        # of the differentiated step removes the (C, C) table from the
        # backward graph.  Unlike the FedGKD teachers there is no forward
        # to save, so the aux tensor is a wash on memory traffic — the
        # executors only run this on the batched paths where it is fused
        # into the round-level precompute dispatch anyway.
        del model, x, mask
        return {"teacher": payload["label_logits"][y]}    # (N, C)

    def loss_fn(self, model):
        beta, temp = self.beta, self.temperature

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            teacher = (aux["teacher"] if aux is not None
                       else payload["label_logits"][y])   # (B, C)
            kd = D.masked_mean(D.kl_divergence(teacher, logits, temp), mask)
            ce = D.cross_entropy(logits, y, mask=mask)
            return ce + beta * payload["enable"] * kd, {"kd": kd}

        return loss

    def client_finalize(self, model, params, x, y, mask, payload):
        logits = model.apply(params, x)
        c = logits.shape[-1]
        onehot = jax.nn.one_hot(y, c, dtype=jnp.float32) * mask[:, None]
        sums = onehot.T @ logits                          # (C, C)
        counts = jnp.sum(onehot, axis=0)                  # (C,)
        return {"logit_sums": sums, "label_counts": counts}

    def server_update(self, server, uploads, weights, model, val_batch=None,
                      n_clients=None):
        server = super().server_update(server, uploads, weights, model,
                                       val_batch, n_clients)
        sums = sum(u["logit_sums"] for u in uploads)
        counts = sum(u["label_counts"] for u in uploads)
        server["label_logits"] = sums / jnp.maximum(counts[:, None], 1.0)
        server["have_logits"] = jnp.ones((), jnp.float32)
        return server


# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _GenCfg:
    noise_dim: int = 32
    hidden: int = 128
    steps: int = 50
    lr: float = 1e-3
    alpha: float = 1.0       # client regularization coefficient


class FedGen(Algorithm):
    """Data-free KD with a server-trained feature generator (Zhu et al.).

    Server: trains G(z, y) -> penultimate feature so the clients' (uploaded)
    classifier heads, weighted by their label counts, classify it as y.
    Client: adds CE of its own head on generated features for labels drawn
    from the global label distribution.
    """

    name = "fedgen"

    def __init__(self, alpha: float = 1.0, noise_dim: int = 32,
                 hidden: int = 128, gen_steps: int = 50, **kw):
        super().__init__(alpha=alpha, **kw)
        self.gcfg = _GenCfg(noise_dim=noise_dim, hidden=hidden,
                            steps=gen_steps, alpha=alpha)

    # generator params / apply -------------------------------------------
    def _gen_init(self, rng, num_classes, feat_dim):
        k1, k2 = jax.random.split(rng)
        h = self.gcfg.hidden
        return {"fc1": layers.dense_bias_init(k1, self.gcfg.noise_dim + num_classes, h),
                "fc2": layers.dense_bias_init(k2, h, feat_dim)}

    @staticmethod
    def _gen_apply(gp, z, y_onehot):
        h = jax.nn.relu(layers.dense(gp["fc1"],
                                     jnp.concatenate([z, y_onehot], -1)))
        return layers.dense(gp["fc2"], h)

    def init_server(self, global_params, model, num_classes):
        raise TypeError(
            "FedGen needs a data probe to size the generator's feature "
            "output; call init_server_with_probe(global_params, model, "
            "num_classes, probe_x) instead (the FL loop does this).")

    # the FL loop calls this variant (needs a data probe for feature dim)
    def init_server_with_probe(self, global_params, model, num_classes, probe_x):
        feat_dim = model.features(global_params, probe_x[:1]).shape[-1]
        rng = jax.random.PRNGKey(17)
        return {"global": global_params, "round": 0,
                "gen": self._gen_init(rng, num_classes, feat_dim),
                "num_classes": num_classes,
                "label_dist": jnp.ones((num_classes,), jnp.float32) / num_classes}

    def round_payload(self, server, rng):
        return {"gen": server["gen"], "label_dist": server["label_dist"],
                "rng": rng}

    def loss_fn(self, model):
        alpha, nd = self.gcfg.alpha, self.gcfg.noise_dim

        def head_apply(params, feats):
            return layers.dense(params["fc"], feats)

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            ce = D.cross_entropy(logits, y, mask=mask)
            b = x.shape[0]
            c = payload["label_dist"].shape[0]
            y_eff = y if mask is None else y * mask.astype(y.dtype)
            rng = jax.random.fold_in(payload["rng"], jnp.sum(y_eff))
            k1, k2 = jax.random.split(rng)
            y_gen = jax.random.categorical(
                k1, jnp.log(payload["label_dist"] + 1e-9)[None, :].repeat(b, 0))
            z = jax.random.normal(k2, (b, nd))
            feats = jax.lax.stop_gradient(
                self._gen_apply(payload["gen"], z, jax.nn.one_hot(y_gen, c)))
            gen_logits = head_apply(params, feats)
            reg = D.cross_entropy(gen_logits, y_gen, mask=mask)
            return ce + alpha * reg, {"gen_ce": reg}

        return loss

    def client_finalize(self, model, params, x, y, mask, payload):
        c = payload["label_dist"].shape[0]
        # one-hot sum instead of bincount so the hook stays vmappable
        counts = jnp.sum(jax.nn.one_hot(y, c, dtype=jnp.float32)
                         * mask[:, None], axis=0)
        return {"head": params["fc"], "label_counts": counts}

    def server_update(self, server, uploads, weights, model, val_batch=None,
                      n_clients=None):
        server = Algorithm.server_update(self, server, uploads, weights, model)
        c = server["num_classes"]
        counts = sum(u["label_counts"] for u in uploads)
        server["label_dist"] = counts / jnp.maximum(jnp.sum(counts), 1.0)
        heads = [u["head"] for u in uploads]
        head_w = jnp.stack([u["label_counts"] for u in uploads])  # (K, C)
        head_w = head_w / jnp.maximum(jnp.sum(head_w, 0, keepdims=True), 1.0)
        gen = server["gen"]
        nd = self.gcfg.noise_dim
        rng = jax.random.PRNGKey(1000 + server["round"])

        def gen_loss(gp, rng):
            k1, k2 = jax.random.split(rng)
            y = jax.random.randint(k1, (64,), 0, c)
            z = jax.random.normal(k2, (64, nd))
            feats = self._gen_apply(gp, z, jax.nn.one_hot(y, c))
            total = 0.0
            for k, head in enumerate(heads):
                logits = layers.dense(head, feats)
                w = head_w[k][y]                        # weight by label counts
                logp = jax.nn.log_softmax(logits, -1)
                total = total - jnp.mean(
                    w * jnp.take_along_axis(logp, y[:, None], -1)[:, 0])
            return total

        @jax.jit
        def gen_step(gp, rng):
            g = jax.grad(gen_loss)(gp, rng)
            return jax.tree_util.tree_map(
                lambda p, gr: p - self.gcfg.lr * gr, gp, g)

        for i in range(self.gcfg.steps):
            gen = gen_step(gen, jax.random.fold_in(rng, i))
        server["gen"] = gen
        return server


# ---------------------------------------------------------------------------

class SCAFFOLD(Algorithm):
    """Karimireddy et al. 2019: control variates correct client drift.

    Local gradient is corrected by (c − c_k); after local training the
    client updates its control variate with option-II:
        c_k ← c_k − c + (w_t − w_k)/(K_steps·η).
    Cited by the paper as the local-correction alternative to KD; included
    as an extra baseline beyond the paper's evaluated set.
    """

    name = "scaffold"

    def __init__(self, lr: float = 0.05, local_steps_hint: int = 20, **kw):
        super().__init__(**kw)
        self.lr = lr
        self.local_steps_hint = local_steps_hint

    def init_server(self, global_params, model, num_classes):
        zeros = jax.tree_util.tree_map(jnp.zeros_like, global_params)
        return {"global": global_params, "round": 0, "c": zeros}

    def round_payload(self, server, rng):
        return {"c": server["c"], "anchor": server["global"]}

    def init_client_state(self, client_id, global_params):
        return {"c_k": jax.tree_util.tree_map(jnp.zeros_like, global_params)}

    def loss_fn(self, model):
        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            ce = D.cross_entropy(logits, y, mask=mask)
            # linear correction term: <(c − c_k), w> has gradient (c − c_k)
            corr = sum(
                jnp.sum((c - ck).astype(jnp.float32) * w.astype(jnp.float32))
                for c, ck, w in zip(
                    jax.tree_util.tree_leaves(payload["c"]),
                    jax.tree_util.tree_leaves(client_state["c_k"]),
                    jax.tree_util.tree_leaves(params)))
            return ce + corr, {}

        return loss

    def update_client_state(self, client_state, params, payload=None):
        return client_state  # updated in server_update via uploads

    def server_update(self, server, uploads, weights, model, val_batch=None,
                      n_clients=None):
        # c_k update (option II) folded here: Δc_k = (w_t − w_k)/(K·η) − c.
        # The round's anchor/control variate are still in the server state at
        # this point (uploading K broadcast copies of them would be waste).
        k_eta = self.local_steps_hint * self.lr
        anchor, c_global = server["global"], server["c"]
        deltas = []
        for u in uploads:
            d = jax.tree_util.tree_map(
                lambda wt, wk, c: (wt.astype(jnp.float32)
                                   - wk.astype(jnp.float32)) / k_eta - c,
                anchor, u["params"], c_global)
            deltas.append(d)
        mean_delta = jax.tree_util.tree_map(
            lambda *xs: sum(xs) / len(xs), *deltas)
        # participation fraction |S|/K over the TOTAL population; without
        # n_clients (direct server_update calls) fall back to full
        # participation, which keeps the old behaviour for |S| == K
        frac = len(uploads) / max(1, n_clients if n_clients is not None
                                  else len(uploads))
        server = Algorithm.server_update(self, server, uploads, weights, model)
        server["c"] = jax.tree_util.tree_map(
            lambda c, d: c + frac * d, server["c"], mean_delta)
        return server


class FedDyn(Algorithm):
    """Acar et al. 2020: dynamic regularization — each client keeps a
    first-order dual state h_k; local objective adds −<h_k, w> +
    (α/2)‖w − w_t‖²."""

    name = "feddyn"

    def __init__(self, alpha: float = 0.01, **kw):
        super().__init__(alpha=alpha, **kw)
        self.alpha = alpha

    def round_payload(self, server, rng):
        return {"anchor": server["global"]}

    def init_client_state(self, client_id, global_params):
        return {"h": jax.tree_util.tree_map(jnp.zeros_like, global_params)}

    def loss_fn(self, model):
        a = self.alpha

        def loss(params, payload, client_state, x, y, mask=None, aux=None):
            logits = model.apply(params, x)
            ce = D.cross_entropy(logits, y, mask=mask)
            lin = sum(jnp.sum(h.astype(jnp.float32) * w.astype(jnp.float32))
                      for h, w in zip(
                          jax.tree_util.tree_leaves(client_state["h"]),
                          jax.tree_util.tree_leaves(params)))
            prox = 0.5 * a * D.param_sq_dist(params, payload["anchor"])
            return ce - lin + prox, {}

        return loss

    def update_client_state(self, client_state, params, payload=None):
        # dual update: h_k <- h_k - alpha*(w_k - w_t)
        a = self.alpha
        return {"h": jax.tree_util.tree_map(
            lambda h, wk, wt: h - a * (wk.astype(h.dtype) - wt.astype(h.dtype)),
            client_state["h"], params, payload["anchor"])}


_REGISTRY = {
    "fedavg": Algorithm,
    "fedprox": FedProx,
    "fedgkd": FedGKD,
    "fedgkd+": FedGKDPlus,
    "fedgkd-vote": FedGKDVote,
    "moon": MOON,
    "feddistill+": FedDistillPlus,
    "fedgen": FedGen,
    "scaffold": SCAFFOLD,
    "feddyn": FedDyn,
}


def make(name: str, **kw) -> Algorithm:
    return _REGISTRY[name](**kw)


def available() -> list[str]:
    return sorted(_REGISTRY)
