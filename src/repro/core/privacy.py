"""Differential-privacy hook (DP-FedAvg style, McMahan et al. 2018).

The paper states FedGKD "is compatible with many privacy protection methods
like differential privacy" — this module makes that concrete: client model
DELTAS are L2-clipped and Gaussian noise is added at aggregation.  Because
FedGKD's teacher is built purely from past (already-noised) global models,
the KD term composes with DP for free — no extra privacy cost.

Usage:  fl_loop.run_federated(..., dp=DPConfig(clip_norm=1.0,
                                               noise_multiplier=0.5))
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim import global_norm


@dataclasses.dataclass(frozen=True)
class DPConfig:
    clip_norm: float = 1.0         # per-client delta L2 bound C
    noise_multiplier: float = 0.5  # σ; noise std = σ·C / n_sampled
    seed: int = 0

    def noise_std(self, n_sampled: int) -> float:
        return self.noise_multiplier * self.clip_norm / max(1, n_sampled)


def clip_delta(new_params: Any, anchor: Any, clip_norm: float) -> Any:
    """Return anchor + clip(new − anchor): the paper's update, L2-bounded."""
    delta = jax.tree_util.tree_map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
        new_params, anchor)
    norm = global_norm(delta)
    scale = jnp.minimum(1.0, clip_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(
        lambda b, d: (b.astype(jnp.float32) + scale * d).astype(b.dtype),
        anchor, delta)


def add_noise(params: Any, std: float, rng: jax.Array) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(rng, len(leaves))
    noised = [
        (x.astype(jnp.float32)
         + std * jax.random.normal(k, x.shape, jnp.float32)).astype(x.dtype)
        for x, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noised)


def privatize_uploads(uploads: list[dict], anchor: Any, dp: DPConfig,
                      round_idx: int) -> list[dict]:
    """Clip every client's delta; noise is added once post-aggregation by
    ``noise_aggregate`` (equivalent under weighted mean, cheaper)."""
    return [dict(u, params=clip_delta(u["params"], anchor, dp.clip_norm))
            for u in uploads]


def noise_aggregate(aggregated: Any, dp: DPConfig, n_sampled: int,
                    round_idx: int) -> Any:
    rng = jax.random.fold_in(jax.random.PRNGKey(dp.seed), round_idx)
    return add_noise(aggregated, dp.noise_std(n_sampled), rng)
