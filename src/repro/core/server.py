"""Server side: weighted aggregation + the FedGKD global-model buffer.

``ModelBuffer`` is the M-deep FIFO of historical global weights (Alg. 1,
line 11).  For FedGKD the server ships only the fused mean (communication =
2× FedAvg, == 1× when M == 1); FedGKD-VOTE ships all M entries.

Staleness-aware aggregation (the async path)
--------------------------------------------
The buffered-asynchronous server (``fl_loop`` with ``executor="async"``)
aggregates a buffer of B client updates, each tagged with the global
version it STARTED from; ``staleness = current_version - start_version``.
``async_aggregation_weights`` combines the FedAvg data weights with a
pluggable per-update staleness multiplier (``staleness_scale``):

    constant      stale updates count like fresh ones
    polynomial    (1 + s)^(-a) — FedAsync-style polynomial decay
    fedgkd        polynomial decay, but updates past ``cutoff`` are
                  DROPPED from parameter averaging (weight 0) and instead
                  absorbed into the KD teacher buffer via the algorithm's
                  ``absorb_stale`` hook — stale knowledge distills rather
                  than drags the global model backwards

Invariants the property suite pins down: scales are non-negative, the
normalized weights sum to 1, and the polynomial scale is monotone
non-increasing in staleness.
"""
from __future__ import annotations

import collections
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.distillation import ensemble_average

STALENESS_SCHEMES = ("constant", "polynomial", "fedgkd")


def weighted_average(params_list: list[Any], weights: list[float]) -> Any:
    """FedAvg aggregation  w ← Σ_k (n_k/n)·w_k  (Alg. 1 line 14)."""
    total = float(sum(weights))
    norm = [w / total for w in weights]

    def agg(*leaves):
        acc = norm[0] * leaves[0].astype(jnp.float32)
        for w, leaf in zip(norm[1:], leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(agg, *params_list)


def staleness_scale(staleness: float, scheme: str = "polynomial", *,
                    a: float = 0.5, cutoff: "float | None" = None) -> float:
    """Per-update multiplier for an update that started ``staleness``
    versions ago.  Non-negative; ``polynomial`` is monotone non-increasing
    in staleness; ``constant`` is exactly 1.0 (so the async path with zero
    staleness reproduces the synchronous weights bit-for-bit)."""
    if scheme not in STALENESS_SCHEMES:
        raise ValueError(f"unknown staleness scheme {scheme!r}; "
                         f"available: {STALENESS_SCHEMES}")
    s = float(staleness)
    assert s >= 0.0, f"negative staleness {s}"
    if scheme == "constant":
        return 1.0
    if scheme == "fedgkd" and cutoff is not None and s > cutoff:
        return 0.0
    return (1.0 + s) ** (-a)


def async_aggregation_weights(data_weights: Sequence[float],
                              staleness: Sequence[float],
                              scheme: str = "polynomial", *,
                              a: float = 0.5,
                              cutoff: "float | None" = None,
                              normalize: bool = True) -> list[float]:
    """Combine FedAvg data weights with staleness multipliers.

    With ``normalize=True`` the result is a distribution (non-negative,
    sums to 1).  ``normalize=False`` returns the raw products for callers
    that feed ``weighted_average`` (which normalizes internally) — under
    the constant scheme the raw products ARE the synchronous n_k weights.
    If every update scaled to zero (an all-stale buffer past the fedgkd
    cutoff) the data weights are used unscaled: the aggregation must stay
    well-defined, and the absorb path has already captured the knowledge.
    """
    assert len(data_weights) == len(staleness)
    raw = [float(n) * staleness_scale(s, scheme, a=a, cutoff=cutoff)
           for n, s in zip(data_weights, staleness)]
    if sum(raw) <= 0.0:
        raw = [float(n) for n in data_weights]
    if not normalize:
        return raw
    total = sum(raw)
    return [r / total for r in raw]


class ModelBuffer:
    """FIFO of the latest M global models.

    Every pushed model gets a monotonically increasing version number so
    downstream consumers (the executor teacher-logit cache — see
    ``repro.core.executor``) can tell WHICH buffer entries changed between
    rounds: a push replaces one entry and leaves M−1 identical.
    """

    def __init__(self, size: int):
        assert size >= 1
        self.size = size
        self._buf: collections.deque = collections.deque(maxlen=size)
        self._versions: collections.deque = collections.deque(maxlen=size)
        self._next_version = 0

    def push(self, params: Any) -> None:
        self._buf.append(params)
        self._versions.append(self._next_version)
        self._next_version += 1

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def models(self) -> list[Any]:
        """Newest-first list of buffered global models."""
        return list(reversed(self._buf))

    @property
    def versions(self) -> list[int]:
        """Newest-first version ids, aligned with ``models``."""
        return list(reversed(self._versions))

    def fused(self) -> Any:
        """FedGKD ensemble teacher  w̄_t = mean of buffer."""
        assert self._buf, "empty buffer"
        return ensemble_average(list(self._buf))
