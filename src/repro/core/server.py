"""Server side: weighted aggregation + the FedGKD global-model buffer.

``ModelBuffer`` is the M-deep FIFO of historical global weights (Alg. 1,
line 11).  For FedGKD the server ships only the fused mean (communication =
2× FedAvg, == 1× when M == 1); FedGKD-VOTE ships all M entries.

Update validation (the fault-tolerance gate)
--------------------------------------------
``validate_update`` is the server's admission check on every client
upload when fault handling is on (``run_federated(faults=)``): non-finite
parameters (a diverged or corrupted client) and norm outliers (an update
whose L2 norm exceeds ``FaultPolicy.max_norm_mult`` × the current global's)
are rejected BEFORE they reach aggregation or the FedGKD teacher buffer —
a poisoned historical teacher would distill its damage into every
subsequent local step.  ``FaultPolicy`` also owns the degradation knobs:
``quorum_frac`` (a sync round aggregates once this fraction of the cohort
survives, weights renormalized over survivors) and the capped exponential
retry backoff applied to crashed/rejected clients on the simulated clock.

Staleness-aware aggregation (the async path)
--------------------------------------------
The buffered-asynchronous server (``fl_loop`` with ``executor="async"``)
aggregates a buffer of B client updates, each tagged with the global
version it STARTED from; ``staleness = current_version - start_version``.
``async_aggregation_weights`` combines the FedAvg data weights with a
pluggable per-update staleness multiplier (``staleness_scale``):

    constant      stale updates count like fresh ones
    polynomial    (1 + s)^(-a) — FedAsync-style polynomial decay
    fedgkd        polynomial decay, but updates past ``cutoff`` are
                  DROPPED from parameter averaging (weight 0) and instead
                  absorbed into the KD teacher buffer via the algorithm's
                  ``absorb_stale`` hook — stale knowledge distills rather
                  than drags the global model backwards

Invariants the property suite pins down: scales are non-negative, the
normalized weights sum to 1, and the polynomial scale is monotone
non-increasing in staleness.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.distillation import ensemble_average

STALENESS_SCHEMES = ("constant", "polynomial", "fedgkd")


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """How the server degrades under client failures.

        quorum_frac     a sync round proceeds once this fraction of the
                        sampled cohort has produced a VALID update
                        (weights renormalize over the survivors)
        max_retries     retry attempts per round (sync) / per client
                        (async re-dispatch) before giving up on the
                        failed clients
        backoff_base    first retry waits this many virtual seconds;
        backoff_cap     each further attempt doubles it, capped here
        max_norm_mult   ``validate_update`` rejects an update whose L2
                        norm exceeds this multiple of the global's
    """
    quorum_frac: float = 0.6
    max_retries: int = 3
    backoff_base: float = 1.0
    backoff_cap: float = 30.0
    max_norm_mult: float = 10.0

    def __post_init__(self):
        if not (0.0 < self.quorum_frac <= 1.0):
            raise ValueError(f"quorum_frac in (0, 1], got {self.quorum_frac}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    def backoff(self, attempt: int) -> float:
        """Capped exponential backoff for retry ``attempt`` (1-based)."""
        return min(self.backoff_cap,
                   self.backoff_base * (2.0 ** (attempt - 1)))


def first_nonfinite_path(tree: Any) -> Optional[str]:
    """'/'-joined path of the first leaf containing NaN/Inf, else None.
    Integer/bool leaves are always finite and skipped without transfer."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        arr = jnp.asarray(leaf)
        if not jnp.issubdtype(arr.dtype, jnp.inexact):
            continue
        if not bool(jnp.all(jnp.isfinite(arr.astype(jnp.float32)))):
            return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
    return None


def _global_norm(tree: Any) -> float:
    leaves = [jnp.asarray(l) for l in jax.tree_util.tree_leaves(tree)]
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return float(jnp.sqrt(sq))


def validate_update(params: Any, ref_params: Any = None, *,
                    max_norm_mult: float = 10.0) -> tuple[bool, str]:
    """Admission check for one client upload: ``(True, "ok")`` or
    ``(False, reason)`` with reason ``"nonfinite:<leaf path>"`` or
    ``"norm:<ratio>x"``.  The norm gate compares against ``ref_params``
    (the current global) with a floor of 1.0 so a near-zero reference
    cannot reject everything."""
    bad = first_nonfinite_path(params)
    if bad is not None:
        return False, f"nonfinite:{bad}"
    if ref_params is not None and max_norm_mult is not None:
        ref = max(_global_norm(ref_params), 1.0)
        ratio = _global_norm(params) / ref
        if ratio > max_norm_mult:
            return False, f"norm:{ratio:.1f}x"
    return True, "ok"


def weighted_average(params_list: list[Any], weights: list[float]) -> Any:
    """FedAvg aggregation  w ← Σ_k (n_k/n)·w_k  (Alg. 1 line 14)."""
    total = float(sum(weights))
    norm = [w / total for w in weights]

    def agg(*leaves):
        acc = norm[0] * leaves[0].astype(jnp.float32)
        for w, leaf in zip(norm[1:], leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(agg, *params_list)


def staleness_scale(staleness: float, scheme: str = "polynomial", *,
                    a: float = 0.5, cutoff: "float | None" = None) -> float:
    """Per-update multiplier for an update that started ``staleness``
    versions ago.  Non-negative; ``polynomial`` is monotone non-increasing
    in staleness; ``constant`` is exactly 1.0 (so the async path with zero
    staleness reproduces the synchronous weights bit-for-bit)."""
    if scheme not in STALENESS_SCHEMES:
        raise ValueError(f"unknown staleness scheme {scheme!r}; "
                         f"available: {STALENESS_SCHEMES}")
    s = float(staleness)
    assert s >= 0.0, f"negative staleness {s}"
    if scheme == "constant":
        return 1.0
    if scheme == "fedgkd" and cutoff is not None and s > cutoff:
        return 0.0
    return (1.0 + s) ** (-a)


def async_aggregation_weights(data_weights: Sequence[float],
                              staleness: Sequence[float],
                              scheme: str = "polynomial", *,
                              a: float = 0.5,
                              cutoff: "float | None" = None,
                              normalize: bool = True) -> list[float]:
    """Combine FedAvg data weights with staleness multipliers.

    With ``normalize=True`` the result is a distribution (non-negative,
    sums to 1).  ``normalize=False`` returns the raw products for callers
    that feed ``weighted_average`` (which normalizes internally) — under
    the constant scheme the raw products ARE the synchronous n_k weights.
    If every update scaled to zero (an all-stale buffer past the fedgkd
    cutoff) the data weights are used unscaled: the aggregation must stay
    well-defined, and the absorb path has already captured the knowledge.
    """
    assert len(data_weights) == len(staleness)
    raw = [float(n) * staleness_scale(s, scheme, a=a, cutoff=cutoff)
           for n, s in zip(data_weights, staleness)]
    if sum(raw) <= 0.0:
        raw = [float(n) for n in data_weights]
    if not normalize:
        return raw
    total = sum(raw)
    return [r / total for r in raw]


def _trees_identical(a: Any, b: Any) -> bool:
    """Bitwise pytree equality (structure + every array element)."""
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb:
        return False
    for x, y in zip(la, lb):
        x, y = jnp.asarray(x), jnp.asarray(y)
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if not bool(jnp.all(x == y)):
            return False
    return True


class ModelBuffer:
    """FIFO of the latest M global models.

    Every pushed model gets a monotonically increasing version number so
    downstream consumers (the executor teacher-logit cache — see
    ``repro.core.executor``) can tell WHICH buffer entries changed between
    rounds: a push replaces one entry and leaves M−1 identical.

    ``push`` is hardened as the last line of defense for the KD teacher
    ensemble: non-finite candidates raise (a poisoned teacher distills
    its damage into every subsequent local step — the quarantine in the
    fault-handling loop should have filtered them long before here), and
    a candidate bitwise-identical to the current head is a no-op
    returning False — no version bump, so the executor part-caches stay
    warm and a retry/replay can never double-insert the same teacher.
    """

    def __init__(self, size: int):
        assert size >= 1
        self.size = size
        self._buf: collections.deque = collections.deque(maxlen=size)
        self._versions: collections.deque = collections.deque(maxlen=size)
        self._next_version = 0

    def push(self, params: Any) -> bool:
        bad = first_nonfinite_path(params)
        if bad is not None:
            raise ValueError(
                f"ModelBuffer.push: non-finite teacher candidate at "
                f"leaf {bad!r} — rejected updates must be quarantined "
                f"before they reach the KD buffer")
        if self._buf and _trees_identical(params, self._buf[-1]):
            return False
        self._buf.append(params)
        self._versions.append(self._next_version)
        self._next_version += 1
        return True

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def models(self) -> list[Any]:
        """Newest-first list of buffered global models."""
        return list(reversed(self._buf))

    @property
    def versions(self) -> list[int]:
        """Newest-first version ids, aligned with ``models``."""
        return list(reversed(self._versions))

    def fused(self) -> Any:
        """FedGKD ensemble teacher  w̄_t = mean of buffer."""
        assert self._buf, "empty buffer"
        return ensemble_average(list(self._buf))
