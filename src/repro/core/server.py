"""Server side: weighted aggregation + the FedGKD global-model buffer.

``ModelBuffer`` is the M-deep FIFO of historical global weights (Alg. 1,
line 11).  For FedGKD the server ships only the fused mean (communication =
2× FedAvg, == 1× when M == 1); FedGKD-VOTE ships all M entries.
"""
from __future__ import annotations

import collections
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.distillation import ensemble_average


def weighted_average(params_list: list[Any], weights: list[float]) -> Any:
    """FedAvg aggregation  w ← Σ_k (n_k/n)·w_k  (Alg. 1 line 14)."""
    total = float(sum(weights))
    norm = [w / total for w in weights]

    def agg(*leaves):
        acc = norm[0] * leaves[0].astype(jnp.float32)
        for w, leaf in zip(norm[1:], leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(agg, *params_list)


class ModelBuffer:
    """FIFO of the latest M global models.

    Every pushed model gets a monotonically increasing version number so
    downstream consumers (the executor teacher-logit cache — see
    ``repro.core.executor``) can tell WHICH buffer entries changed between
    rounds: a push replaces one entry and leaves M−1 identical.
    """

    def __init__(self, size: int):
        assert size >= 1
        self.size = size
        self._buf: collections.deque = collections.deque(maxlen=size)
        self._versions: collections.deque = collections.deque(maxlen=size)
        self._next_version = 0

    def push(self, params: Any) -> None:
        self._buf.append(params)
        self._versions.append(self._next_version)
        self._next_version += 1

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def models(self) -> list[Any]:
        """Newest-first list of buffered global models."""
        return list(reversed(self._buf))

    @property
    def versions(self) -> list[int]:
        """Newest-first version ids, aligned with ``models``."""
        return list(reversed(self._versions))

    def fused(self) -> Any:
        """FedGKD ensemble teacher  w̄_t = mean of buffer."""
        assert self._buf, "empty buffer"
        return ensemble_average(list(self._buf))
