"""Client side: the local update loop (Alg. 1, CLIENTUPDATE).

Each sampled client runs E epochs of minibatch SGD on
    F_k(w) + <algorithm-specific regularizer>(w; payload, client_state)

The local pass is expressed as a ``lax.scan`` over a stacked batch tensor
``(S, B, ...)`` with two masks:

    example mask (S, B)   zero-weight for examples padded onto a ragged
                          batch — they contribute nothing to loss/grads
    step mask    (S,)     False for steps padded onto a client with fewer
                          batches than its neighbours — the whole step is
                          an identity on (params, opt_state)

and an optional per-step ``aux`` pytree: round-constant tensors gathered
from the algorithm's ``precompute_aux`` stage (teacher logits etc. — see
``repro.core.executor``), leaves shaped ``(S, B, ...)``.  ``()`` (the empty
pytree) means "no precompute" and is delivered to the loss as ``aux=None``.

That makes the SAME function usable three ways by the executors in
``repro.core.executor``: jitted per client (SequentialExecutor), vmapped
over a stacked client axis (VmapExecutor), or vmapped inside a shard_map
shard (ShardMapExecutor).  ``loss_fn`` comes from the algorithm and must be
pure pytree-in/pytree-out: ``loss(params, payload, client_state, x, y,
mask=None, aux=None) -> (scalar, metrics_dict)``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, apply_updates


def _aux_or_none(aux: Any) -> Any:
    """Normalize the executor convention: the empty pytree means no aux."""
    return None if isinstance(aux, tuple) and len(aux) == 0 else aux


def make_step(loss_fn: Callable, opt: Optimizer, jit: bool = True) -> Callable:
    """One masked SGD step.

    ``loss_fn(params, payload, client_state, x, y, mask, aux) -> (loss,
    metrics)``.  Returns ``step(params, opt_state, payload, client_state,
    x, y, mask, aux, lr)``; pass ``aux=()`` when there is no precompute.
    """

    def step(params, opt_state, payload, client_state, x, y, mask, aux, lr):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, payload, client_state, x, y, mask, _aux_or_none(aux))
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        return apply_updates(params, updates), opt_state, loss, metrics

    return jax.jit(step) if jit else step


def make_local_update(loss_fn: Callable, opt: Optimizer) -> Callable:
    """Build the scan-based client pass.

    Returns ``local_update(params, payload, client_state, xs, ys, ex_mask,
    aux, step_mask, lr) -> (new_params, mean_loss)`` where ``xs/ys`` carry a
    leading step axis ``S`` and every batch has a uniform size ``B``.
    ``aux`` is the per-step precompute pytree (leaves ``(S, B, ...)``) or
    ``()``.  Masked-out steps leave params and optimizer state untouched
    (so a padded client is bit-identical to one trained on its real steps
    only); masked-out examples are zero-weighted inside the loss.
    """
    step = make_step(loss_fn, opt, jit=False)

    def local_update(params: Any, payload: Any, client_state: Any,
                     xs: jax.Array, ys: jax.Array, ex_mask: jax.Array,
                     aux: Any, step_mask: jax.Array, lr) -> tuple[Any, jax.Array]:
        opt_state = opt.init(params)

        def body(carry, batch):
            p, o = carry
            x, y, m, aux_b, live = batch
            p2, o2, loss, _ = step(p, o, payload, client_state, x, y, m,
                                   aux_b, lr)
            keep = lambda new, old: jnp.where(live, new, old)
            p = jax.tree_util.tree_map(keep, p2, p)
            o = jax.tree_util.tree_map(keep, o2, o)
            return (p, o), jnp.where(live, loss, 0.0)

        (params, _), losses = jax.lax.scan(
            body, (params, opt_state), (xs, ys, ex_mask, aux, step_mask))
        denom = jnp.maximum(1.0, jnp.sum(step_mask.astype(jnp.float32)))
        return params, jnp.sum(losses) / denom

    return local_update


def make_batched_local_update(batched_loss_fn: Callable, opt: Optimizer,
                              unroll_limit: int = 8) -> Callable:
    """The whole-cohort client pass for CLIENT-BATCHED models.

    Same call signature and outputs as ``vmap(make_local_update(...))`` —
    ``(global_params, payload, states_stacked, xs (K, S, B, ...), ys,
    ex_mask, aux, step_mask (K, S), lr) -> (params (K, ...), mean_loss
    (K,))`` — but instead of vmapping the per-client scan it broadcasts the
    global params to a stacked ``(K, ...)`` pytree and drives
    ``batched_loss_fn`` (one fused forward+backward over the cohort; conv
    backbones route through ``kernels.grouped_conv``).  Per-client masking
    semantics are identical: a client's padded step leaves ITS params and
    opt state untouched; padded examples are zero-weighted in the loss.

    Rounds with at most ``unroll_limit`` steps run as an unrolled step
    loop: on CPU, XLA executes a ``lax.scan`` over bodies this size
    drastically slower than the identical unrolled program (measured ~19x
    on resnet8 — the while-loop body misses the fusion/threading the
    straight-line program gets), and the benchmark round counts sit well
    under the limit.  Longer rounds fall back to ``lax.scan`` to bound
    compile time.
    """

    def step(params, opt_state, payload, states, x, y, m, aux_b, lr):
        (_, per), grads = jax.value_and_grad(
            batched_loss_fn, has_aux=True)(params, payload, states, x, y, m,
                                           _aux_or_none(aux_b))
        # the optimizer update IS vmapped (cheap elementwise pytree math, no
        # model ops): scalar state leaves — Adam's step count — stay
        # per-client (K,) exactly as in the vmapped round body, so the
        # per-client keep-mask below can gate every leaf
        updates, opt_state = jax.vmap(
            lambda g, o, p: opt.update(g, o, p, lr))(grads, opt_state,
                                                     params)
        return apply_updates(params, updates), opt_state, per

    def local_update(global_params: Any, payload: Any, states: Any,
                     xs: jax.Array, ys: jax.Array, ex_mask: jax.Array,
                     aux: Any, step_mask: jax.Array, lr):
        k, s = xs.shape[0], xs.shape[1]
        params = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (k,) + l.shape), global_params)
        opt_state = jax.vmap(opt.init)(params)

        def body(carry, batch):
            p, o = carry
            x, y, m, aux_b, live = batch
            p2, o2, per = step(p, o, payload, states, x, y, m, aux_b, lr)
            keep = lambda new, old: jnp.where(
                live.reshape((k,) + (1,) * (new.ndim - 1)), new, old)
            p = jax.tree_util.tree_map(keep, p2, p)
            o = jax.tree_util.tree_map(keep, o2, o)
            return (p, o), jnp.where(live, per, 0.0)

        # step-major views: leaves (K, S, ...) -> (S, K, ...)
        swap = lambda l: jnp.swapaxes(l, 0, 1)
        xs_t, ys_t, m_t = swap(xs), swap(ys), swap(ex_mask)
        aux_t = jax.tree_util.tree_map(swap, aux)
        live_t = swap(step_mask)
        carry = (params, opt_state)
        if s <= unroll_limit:
            losses = []
            for i in range(s):
                aux_i = jax.tree_util.tree_map(lambda l: l[i], aux_t)
                carry, per = body(carry, (xs_t[i], ys_t[i], m_t[i], aux_i,
                                          live_t[i]))
                losses.append(per)
            losses = jnp.stack(losses)                      # (S, K)
        else:
            carry, losses = jax.lax.scan(
                body, carry, (xs_t, ys_t, m_t, aux_t, live_t))
        params = carry[0]
        denom = jnp.maximum(1.0, jnp.sum(step_mask.astype(jnp.float32), 1))
        return params, jnp.sum(losses, 0) / denom

    return local_update
