"""Client side: the local update loop (Alg. 1, CLIENTUPDATE).

Each sampled client runs E epochs of minibatch SGD on
    F_k(w) + <algorithm-specific regularizer>(w; payload, client_state)
The step is jitted ONCE per (algorithm, model) and reused across clients and
rounds — payloads are pytrees with a fixed structure.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ClientData, batch_iterator
from repro.optim import Optimizer, apply_updates


class LocalResult(NamedTuple):
    params: Any
    n_examples: int
    mean_loss: float
    extras: dict


def make_step(loss_fn: Callable, opt: Optimizer) -> Callable:
    """loss_fn(params, payload, client_state, x, y) -> (loss, aux_dict)."""

    @jax.jit
    def step(params, opt_state, payload, client_state, x, y, lr):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, payload, client_state, x, y)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        return apply_updates(params, updates), opt_state, loss, aux

    return step


def local_update(step: Callable, opt: Optimizer, params: Any, payload: Any,
                 client_state: Any, data: ClientData, *, lr: float,
                 batch_size: int, epochs: int, rng: np.random.Generator,
                 max_batches: int | None = None) -> tuple[Any, float]:
    """Run the local epochs; returns (new_params, mean loss)."""
    opt_state = opt.init(params)
    losses = []
    n_done = 0
    for x, y in batch_iterator(rng, data, batch_size, epochs):
        params, opt_state, loss, _ = step(
            params, opt_state, payload, client_state,
            jnp.asarray(x), jnp.asarray(y), lr)
        losses.append(float(loss))
        n_done += 1
        if max_batches is not None and n_done >= max_batches:
            break
    return params, float(np.mean(losses)) if losses else 0.0
