"""Simulated system heterogeneity: the virtual clock behind async rounds.

The source paper attributes client drift to local updates running "through
heterogeneous systems", but a single-host simulation has no heterogeneous
systems — this module supplies them, deterministically.  A ``SystemSim``
owns

  * per-client COMPUTE SPEEDS drawn once from a configurable
    ``SpeedProfile`` (homogeneous / straggler tail / lognormal / uniform),
  * optional AVAILABILITY windows (a client dispatched while off-duty
    starts when its next window opens),
  * a VIRTUAL CLOCK plus an event heap of in-flight client completions.

``dispatch(client, work, tag)`` schedules a completion at
``start + work/speed`` and ``pop()`` consumes the earliest completion,
advancing the clock.  Two invariants the property tests pin down:

  * the clock NEVER goes backwards: completions pop in time order and a
    freshly dispatched completion can never land before the current clock
    (durations are strictly positive);
  * with equal speeds and equal work the order clients complete in is the
    dispatch order, whatever buffer size the consumer drains with —
    simultaneous completions tie-break on a monotone dispatch sequence
    number, never on hash order or wall time.

Determinism: every random draw (speeds, availability phases) comes from
the ``numpy.random.Generator`` handed in at construction — there is no
``random`` module, no wall clock, no global state.  ``derive_rng(seed)``
builds the canonical generator for a training seed (a child stream of the
run's SeedSequence, so the simulation does not perturb the batch/sampling
draws of the equivalent synchronous run).

Fault injection
---------------
``FaultProfile`` + ``FaultInjector`` add client FAILURES on top of the
speed/availability model: per dispatch a client may CRASH (its update
never arrives), TIME OUT (it arrives only after a ``timeout_factor``×
inflated duration — past any reasonable deadline, so the server treats it
as dead), or upload a CORRUPT update (NaN / Inf / exploded-norm
parameters, the three shapes a broken client actually produces).  Draws
come from ``derive_fault_rng(seed)`` — a SECOND child stream, distinct
from the sim stream — so enabling faults perturbs neither the
speed/availability draws nor the main sampling/batch rng: a zero-
probability profile replays the fault-free run bit for bit, and the same
seed fires the same faults whichever executor route
(sequential/vmap/shard_map/async) consumes the dispatch sequence.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, NamedTuple, Optional

import numpy as np

# child-stream key for derive_rng: the sim draws from a stream SPAWNED off
# the training seed so async and sync runs consume the main rng identically
_SIM_STREAM_KEY = 0x5E1F
# a separate child stream for fault draws: faults must not perturb the
# speed/availability stream (or the main rng) so a zero-probability
# profile is bit-identical to no profile at all
_FAULT_STREAM_KEY = 0xFA17

_PROFILE_KINDS = ("homogeneous", "straggler", "lognormal", "uniform")

CORRUPT_MODES = ("nan", "inf", "huge")


def derive_rng(seed: int) -> np.random.Generator:
    """The canonical simulation generator for a training seed."""
    return np.random.default_rng(np.random.SeedSequence(
        entropy=seed, spawn_key=(_SIM_STREAM_KEY,)))


def derive_fault_rng(seed: int) -> np.random.Generator:
    """The canonical FAULT generator for a training seed (its own child
    stream: fault draws never consume the sim or sampling streams)."""
    return np.random.default_rng(np.random.SeedSequence(
        entropy=seed, spawn_key=(_FAULT_STREAM_KEY,)))


@dataclasses.dataclass(frozen=True)
class SpeedProfile:
    """How per-client compute speeds are drawn (speed 1.0 == baseline;
    duration of ``work`` units is ``work / speed``).

        homogeneous   every client at speed 1.0 (the equivalence regime)
        straggler     a ``straggler_frac`` tail runs ``straggler_slowdown``×
                      slower (the paper-style systems-heterogeneity case)
        lognormal     speed ~ LogNormal(0, sigma) — smooth heavy tail
        uniform       speed ~ U[lo, hi]
    """
    kind: str = "homogeneous"
    straggler_frac: float = 0.2
    straggler_slowdown: float = 4.0
    sigma: float = 0.5
    lo: float = 0.5
    hi: float = 2.0

    def __post_init__(self):
        if self.kind not in _PROFILE_KINDS:
            raise ValueError(f"unknown speed profile {self.kind!r}; "
                             f"available: {_PROFILE_KINDS}")


@dataclasses.dataclass(frozen=True)
class Availability:
    """Periodic duty-cycle availability: client ``k`` is reachable during
    ``[n*period + phase_k, n*period + phase_k + duty*period)`` for every
    integer ``n``.  Phases are drawn per client from the sim generator so
    windows are staggered; ``duty=1`` disables the model."""
    period: float = 64.0
    duty: float = 1.0

    def __post_init__(self):
        if not (0.0 < self.duty <= 1.0):
            raise ValueError(f"duty must be in (0, 1], got {self.duty}")
        if self.period <= 0.0:
            raise ValueError(f"period must be positive, got {self.period}")


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Per-dispatch failure model (all probabilities independent draws).

        crash_prob      client dies mid-round: the update never arrives
        timeout_prob    client straggles into the timeout tail: its
                        completion lands at ``timeout_factor`` × the
                        honest duration, past any deadline — the server
                        treats it exactly like a crash, but it is counted
                        separately (and occupies the async event heap for
                        the inflated duration)
        corrupt_prob    the update arrives but is garbage; the corruption
                        MODE is drawn uniformly from ``corrupt_modes``:
                        "nan" / "inf" poison one parameter element,
                        "huge" scales every parameter by ``huge_scale``
                        (finite, but a norm outlier)
        host_crash_prob  correlated HOST fault (multi-host placement): per
                        wave/attempt, each host process dies with this
                        probability, which faults EVERY client of its
                        owned shard subset in that wave at once — the
                        quorum then counts only surviving hosts' validated
                        uploads and retry re-dispatches the absent slice.
                        Drawn one uniform per host in host order, and only
                        when the probability is nonzero, so single-host
                        runs and zero-probability profiles stay
                        bit-identical.

    A profile with all probabilities zero is exactly equivalent to no
    profile: the fault stream is still drawn from, but from its OWN child
    stream (``derive_fault_rng``), so nothing else shifts.
    """
    crash_prob: float = 0.0
    timeout_prob: float = 0.0
    corrupt_prob: float = 0.0
    corrupt_modes: tuple = CORRUPT_MODES
    timeout_factor: float = 16.0
    huge_scale: float = 1e6
    host_crash_prob: float = 0.0

    def __post_init__(self):
        total = self.crash_prob + self.timeout_prob + self.corrupt_prob
        if not (0.0 <= total <= 1.0):
            raise ValueError(
                f"fault probabilities must sum into [0, 1], got {total}")
        if not (0.0 <= self.host_crash_prob <= 1.0):
            raise ValueError(f"host_crash_prob must be in [0, 1], got "
                             f"{self.host_crash_prob}")
        for m in self.corrupt_modes:
            if m not in CORRUPT_MODES:
                raise ValueError(f"unknown corrupt mode {m!r}; "
                                 f"available: {CORRUPT_MODES}")

    @property
    def any(self) -> bool:
        return (self.crash_prob + self.timeout_prob
                + self.corrupt_prob + self.host_crash_prob) > 0.0


class FaultInjector:
    """Seeded per-dispatch fault draws + injection counters.

    ``draw()`` consumes ONE uniform per dispatch (plus one more only when
    a corruption fires, to pick the mode), so the fault sequence is a pure
    function of the seed and the dispatch order — the three synchronous
    executors share a dispatch order (the sampled cohort) and therefore
    fire identical faults.
    """

    def __init__(self, profile: FaultProfile,
                 rng: Optional[np.random.Generator] = None):
        self.profile = profile
        self.rng = rng if rng is not None else derive_fault_rng(0)
        self.counters = {"crashes": 0, "timeouts": 0, "corrupt_injected": 0,
                         "host_crashes": 0}

    def draw(self) -> "tuple[str, str] | None":
        """``None`` (healthy) or ``(kind, mode)`` with kind in
        crash/timeout/corrupt and mode one of ``CORRUPT_MODES`` (empty
        string for non-corrupt kinds)."""
        p = self.profile
        u = self.rng.random()
        if u < p.crash_prob:
            self.counters["crashes"] += 1
            return ("crash", "")
        if u < p.crash_prob + p.timeout_prob:
            self.counters["timeouts"] += 1
            return ("timeout", "")
        if u < p.crash_prob + p.timeout_prob + p.corrupt_prob:
            mode = p.corrupt_modes[
                int(self.rng.integers(len(p.corrupt_modes)))]
            self.counters["corrupt_injected"] += 1
            return ("corrupt", mode)
        return None

    def draw_host_crashes(self, n_hosts: int) -> "tuple[int, ...]":
        """The host ids that crash this wave/attempt: one uniform per host
        in host order (deterministic across all hosts replaying the same
        stream).  MUST only be called when ``profile.host_crash_prob > 0``
        — a zero-probability profile consumes nothing extra here, so
        pre-host-fault runs replay bit for bit."""
        p = self.profile
        assert p.host_crash_prob > 0.0, \
            "draw_host_crashes with host_crash_prob == 0 would shift the " \
            "fault stream of zero-probability runs"
        crashed = tuple(h for h in range(n_hosts)
                        if self.rng.random() < p.host_crash_prob)
        self.counters["host_crashes"] += len(crashed)
        return crashed


def corrupt_params(params: Any, mode: str, huge_scale: float = 1e6) -> Any:
    """Apply one corruption mode to a parameter pytree (pure).

    "nan"/"inf" poison a single element of the first leaf — the subtle
    shape, exercising the validator's full-tree scan rather than handing
    it an all-garbage tensor; "huge" multiplies every leaf by
    ``huge_scale`` — all-finite, caught only by the norm gate.
    """
    import jax
    import jax.numpy as jnp

    if mode == "huge":
        return jax.tree_util.tree_map(lambda l: l * huge_scale, params)
    if mode not in ("nan", "inf"):
        raise ValueError(f"unknown corrupt mode {mode!r}")
    poison = jnp.nan if mode == "nan" else jnp.inf
    leaves, treedef = jax.tree_util.tree_flatten(params)
    first = leaves[0]
    leaves[0] = first.at[(0,) * first.ndim].set(poison)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def draw_speeds(profile: SpeedProfile, n_clients: int,
                rng: np.random.Generator) -> np.ndarray:
    """(K,) float64 per-client speeds, strictly positive."""
    if profile.kind == "homogeneous":
        return np.ones(n_clients)
    if profile.kind == "straggler":
        speeds = np.ones(n_clients)
        slow = rng.random(n_clients) < profile.straggler_frac
        speeds[slow] = 1.0 / profile.straggler_slowdown
        return speeds
    if profile.kind == "lognormal":
        return np.exp(rng.normal(0.0, profile.sigma, n_clients))
    # uniform
    return rng.uniform(profile.lo, profile.hi, n_clients)


class Completion(NamedTuple):
    """One client finishing its local work (popped from the event heap)."""
    time: float     # virtual completion time
    seq: int        # monotone dispatch sequence number (the tie-break)
    client: int
    tag: Any        # caller payload (the async loop stores the update here)


class SystemSim:
    """Virtual clock + in-flight completion heap over K simulated clients.

    ``now`` only moves forward (``pop`` advances it to the completion's
    time); dispatches happen AT ``now`` and complete strictly later.  All
    counters (dispatches, availability delays, total waiting) are plain
    ints/floats derived from seeded draws — two sims built from the same
    generator state replay bit-identically.
    """

    def __init__(self, n_clients: int, profile: Optional[SpeedProfile] = None,
                 availability: Optional[Availability] = None,
                 rng: Optional[np.random.Generator] = None,
                 base_step_time: float = 1.0):
        assert base_step_time > 0.0
        rng = rng if rng is not None else np.random.default_rng(0)
        self.profile = profile if profile is not None else SpeedProfile()
        self.speeds = draw_speeds(self.profile, n_clients, rng)
        assert np.all(self.speeds > 0.0)
        self.availability = availability
        self.phases = (rng.random(n_clients) * availability.period
                       if availability is not None else None)
        self.base_step_time = float(base_step_time)
        self.now = 0.0
        self._heap: list[tuple[float, int, int, Any]] = []
        self._seq = 0
        self.dispatches = 0
        self.availability_delays = 0
        self.total_wait = 0.0

    # -- geometry ---------------------------------------------------------
    def duration(self, client: int, work: float) -> float:
        """Virtual seconds for ``work`` units on ``client``."""
        return self.base_step_time * float(work) / float(self.speeds[client])

    def next_available(self, client: int, t: float) -> float:
        """Earliest time >= t the client's availability window is open."""
        av = self.availability
        if av is None or av.duty >= 1.0:
            return t
        local = (t - self.phases[client]) % av.period
        if local < av.duty * av.period:
            return t
        return t + (av.period - local)

    # -- event machinery --------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._heap)

    def dispatch(self, client: int, work: float, tag: Any = None, *,
                 delay: float = 0.0, slowdown: float = 1.0) -> float:
        """Start ``work`` units on ``client`` at the current clock (or its
        next availability window); returns the scheduled completion time.

        ``delay`` pushes the earliest start past ``now`` (the retry
        path's exponential backoff on the simulated clock); ``slowdown``
        inflates the duration (the fault model's timeout tail).
        """
        earliest = self.now + delay
        start = self.next_available(client, earliest)
        if start > earliest:
            # only the availability wait counts here; the caller tracks
            # its own backoff delay in the fault telemetry
            self.availability_delays += 1
            self.total_wait += start - earliest
        completion = start + self.duration(client, work) * slowdown
        heapq.heappush(self._heap, (completion, self._seq, client, tag))
        self._seq += 1
        self.dispatches += 1
        return completion

    def pop(self) -> Completion:
        """Consume the earliest completion, advancing the clock (monotone:
        remaining heap entries are all >= the popped time)."""
        if not self._heap:
            raise RuntimeError("SystemSim.pop: no in-flight clients")
        t, seq, client, tag = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return Completion(t, seq, client, tag)

    def pop_batch(self, b: int) -> list[Completion]:
        """The next ``b`` completions in time order (the aggregation
        buffer fill of the async server)."""
        if b > len(self._heap):
            raise RuntimeError(
                f"SystemSim.pop_batch({b}): only {len(self._heap)} in flight")
        return [self.pop() for _ in range(b)]

    def stats(self) -> dict:
        return {"sim_time": self.now, "dispatches": self.dispatches,
                "in_flight": self.in_flight,
                "availability_delays": self.availability_delays,
                "total_wait": self.total_wait,
                "speed_min": float(self.speeds.min()),
                "speed_max": float(self.speeds.max()),
                "speed_mean": float(self.speeds.mean())}

    # -- checkpointing ----------------------------------------------------
    def state(self) -> dict:
        """Serializable snapshot of ALL mutable sim state — the clock, the
        in-flight event heap (tags included: the async loop stores upload
        pytrees there, which ``checkpoint.recovery`` encodes leaf by leaf)
        and the counters.  Speeds/phases are included too: they are
        reproducible from the seed, but restoring them makes the snapshot
        self-contained rather than construction-order-dependent."""
        return {"now": float(self.now),
                "heap": list(self._heap),
                "seq": self._seq,
                "dispatches": self.dispatches,
                "availability_delays": self.availability_delays,
                "total_wait": float(self.total_wait),
                # as python-float lists, NOT arrays: float64 arrays would
                # round-trip through jnp's default float32 on decode
                "speeds": [float(s) for s in self.speeds],
                "phases": ([float(p) for p in self.phases]
                           if self.phases is not None else None)}

    def restore(self, state: dict) -> None:
        """Rehydrate from ``state()`` (round-tripped through
        ``checkpoint.recovery``): heap entries come back as tuples in the
        saved order, which is a valid heap — re-heapify anyway so a
        hand-edited snapshot cannot corrupt the pop order."""
        self.now = float(state["now"])
        heap = [(float(t), int(seq), int(client), tag)
                for t, seq, client, tag in state["heap"]]
        heapq.heapify(heap)
        self._heap = heap
        self._seq = int(state["seq"])
        self.dispatches = int(state["dispatches"])
        self.availability_delays = int(state["availability_delays"])
        self.total_wait = float(state["total_wait"])
        self.speeds = np.asarray(state["speeds"], np.float64)
        phases = state.get("phases")
        self.phases = (np.asarray(phases, np.float64)
                       if phases is not None else None)


def measure_step_time(step_fn, *args, warmup: int = 1,
                      repeats: int = 3) -> float:
    """Median wall-clock seconds of one ``step_fn(*args)`` call, with a
    device sync after each — the calibration input for
    ``SystemSim(base_step_time=...)``.

    ``base_step_time`` defaults to 1.0 virtual second per unit of local
    work, so ``sim_time`` is in abstract step units.  Calibrating it to a
    measured per-step device time (wall seconds / local steps in the
    call) turns the virtual clock into a wall-clock PREDICTION:
    ``sim_time * base_step_time`` then estimates real seconds, which is
    what lets ``sim_speedup_vs_sync`` be checked against measured
    throughput (``benchmarks/throughput_bench.py`` records the ratio).
    """
    import time as _time

    import jax as _jax

    for _ in range(max(0, warmup)):
        _jax.block_until_ready(step_fn(*args))
    samples = []
    for _ in range(max(1, repeats)):
        t0 = _time.perf_counter()
        _jax.block_until_ready(step_fn(*args))
        samples.append(_time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2]
