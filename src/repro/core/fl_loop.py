"""Algorithm-agnostic federated training loop (Alg. 1 ServerExecution).

Single-host simulation path used by the paper-reproduction benchmarks.  HOW
the sampled clients run each round is delegated to a pluggable
``ClientExecutor`` (repro.core.executor): sequential reference, batched
vmap (one jitted call trains the whole cohort), or the multi-device
shard_map route (cohort sharded over a ("clients",) mesh with
device-resident client shards).  The multi-device driver for the big
assigned architectures lives in repro/launch/train.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import PaperTask
from repro.core import executor as executor_lib
from repro.core.algorithms import Algorithm, FedGen
from repro.core.distillation import accuracy, cross_entropy
from repro.core.modelzoo import ModelBundle, make_model
from repro.data.pipeline import FederatedData, num_batches as \
    pipeline_num_batches
from repro.optim import adam, sgd


@dataclasses.dataclass
class RoundRecord:
    round: int
    test_acc: float
    test_loss: float
    mean_local_loss: float
    seconds: float
    # -- async extensions (defaults keep synchronous records unchanged) --
    sim_time: float = 0.0        # virtual clock at this aggregation event
    version: int = 0             # global model version AFTER the update
    mean_staleness: float = 0.0  # mean (version - start_version) in buffer
    sampled: tuple = ()          # client ids aggregated this round (sync:
    #                              the sampled cohort) — benchmarks replay
    #                              simulated wall-clock from these


@dataclasses.dataclass
class History:
    algo: str
    records: list[RoundRecord]
    final_params: Any
    local_model_acc: float = 0.0       # last sampled client's local-model acc
    telemetry: dict = dataclasses.field(default_factory=dict)
    #                                  # final RoundContext.telemetry snapshot

    @property
    def best_acc(self) -> float:
        return max(r.test_acc for r in self.records)

    @property
    def final_acc(self) -> float:
        return self.records[-1].test_acc

    def accs(self) -> list[float]:
        return [r.test_acc for r in self.records]


# evaluate() is called every round for every run; re-jitting model.apply
# each call threw away the compiled executable.  One jitted wrapper per
# distinct apply fn (bundles built for the same backbone share it; jax
# retraces per params/input shape underneath as usual).  Bounded FIFO:
# the distilbert bundle creates a fresh apply closure per make_model, so
# an unbounded dict would leak compiled executables across sweep runs
# (and the jitted value strongly references its key, ruling out weakrefs).
_APPLY_CACHE: "collections.OrderedDict[Callable, Callable]" = \
    collections.OrderedDict()
_APPLY_CACHE_MAX = 32


def _cached_apply(model: ModelBundle) -> Callable:
    fn = _APPLY_CACHE.get(model.apply)
    if fn is None:
        fn = jax.jit(model.apply)
        _APPLY_CACHE[model.apply] = fn
        while len(_APPLY_CACHE) > _APPLY_CACHE_MAX:
            _APPLY_CACHE.popitem(last=False)
    return fn


def evaluate(model: ModelBundle, params: Any, x: np.ndarray, y: np.ndarray,
             batch: int = 256) -> tuple[float, float]:
    accs, losses, ns = [], [], []
    apply = _cached_apply(model)
    for i in range(0, len(y), batch):
        xb, yb = jnp.asarray(x[i:i + batch]), jnp.asarray(y[i:i + batch])
        logits = apply(params, xb)
        accs.append(float(accuracy(logits, yb)) * len(yb))
        losses.append(float(cross_entropy(logits, yb)) * len(yb))
        ns.append(len(yb))
    n = sum(ns)
    return sum(accs) / n, sum(losses) / n


def run_federated(task: PaperTask, algo: Algorithm,
                  data: Optional[FederatedData] = None, *,
                  population=None,
                  rounds: Optional[int] = None, seed: int = 0,
                  eval_every: int = 1, max_batches_per_client: int | None = None,
                  verbose: bool = False, width: int = 16,
                  round_callback=None, dp=None,
                  executor: "str | executor_lib.ClientExecutor" = "auto",
                  precompute: "bool | str" = "auto",
                  client_batched: "bool | str" = "auto",
                  faults=None, fault_policy=None,
                  checkpoint_dir: Optional[str] = None,
                  checkpoint_every: int = 1,
                  resume: bool = False) -> History:
    """Run T communication rounds of ``algo`` on the partitioned data.

    ``data`` is the eager in-memory dataset (``FederatedData``); for
    large populations pass ``population=`` (a ``repro.population.
    Population``) instead — clients then materialize lazily through the
    cold/warm/hot tiers, cohorts come from the hierarchical O(cohort)
    sampler (``n_shards=1`` reproduces the flat ``rng.choice`` sequence
    bit-identically), per-client algorithm state moves into the same
    tiers, and tier hit/miss/eviction counters surface on
    ``History.telemetry["population"]``.

    ``executor`` selects the client-execution strategy: ``"sequential"``,
    ``"vmap"``, ``"shard_map"``, ``"async"`` (buffered straggler-aware
    rounds on a simulated heterogeneous system — see ``_run_async`` and
    ``executor_lib.AsyncExecutor`` for the knobs; records then carry
    ``sim_time``/``version``/``mean_staleness``), an executor instance, or
    ``"auto"`` (batched vmap whenever the algorithm supports it).
    ``precompute``
    gates the round-level teacher-precompute stage (the algorithm's
    ``precompute_aux`` hook): ``"auto"`` enables it for the batched
    executors only — on the sequential reference the per-client dispatch
    and host round-trips cost more than the hoisted teacher forward saves
    (see BENCH_executor.json) — while ``True``/``False`` force it; False
    is the inline no-aux pre-pipeline path, kept for equivalence tests
    and benchmarking.  ``client_batched`` gates the batched executors'
    client-batched round body on conv backbones (``"auto"`` uses it when
    the model + algorithm support it; ``False`` forces the historical
    vmapped body — the conv benchmarks' naive baseline).

    ``faults=`` (a ``systemsim.FaultProfile``) turns on fault-tolerant
    rounds: per-dispatch crash/timeout/corrupt draws from a dedicated
    child stream of the seed (identical across executor routes), a
    server-side ``validate_update`` admission gate, quorum aggregation
    with capped-exponential-backoff retries (``fault_policy=``, a
    ``server.FaultPolicy``), and fault counters on
    ``History.telemetry["faults"]``.  A zero-probability profile is
    bit-identical to ``faults=None``.

    ``checkpoint_dir=`` persists the FULL run state every
    ``checkpoint_every`` rounds (params, teacher buffer, rng/sampler
    state, per-client state, round records — ``checkpoint.recovery``);
    ``resume=True`` restores the newest loadable state file from that
    directory (torn files are skipped) and continues bit-identically to
    the uninterrupted run.  This composes with ``executor="async"`` (the
    in-flight event heap, its tagged upload pytrees and the per-client
    retry state serialize with the rest — a kill mid-wave resumes the
    exact wave) and with ``population=`` (the per-client state tiers
    snapshot their warm entries by value and their spill set by
    reference, so resume re-warms lazily from the same spill files;
    stateless algorithms re-init bit-identically and snapshot nothing).
    """
    if (data is None) == (population is None):
        raise ValueError("pass exactly one of data= (eager FederatedData) "
                         "or population= (repro.population.Population)")
    pop = population
    if pop is not None:
        data = pop      # duck-typed: clients[cid] / test_x / sample_cohort
    multihost = pop is not None and getattr(pop, "multihost", False)
    rounds = rounds if rounds is not None else task.rounds
    model = make_model(task, projection_head=algo.needs_projection_head,
                       width=width)
    rng = np.random.default_rng(seed)
    jrng = jax.random.PRNGKey(seed)

    global_params = model.init(jax.random.PRNGKey(seed + 1))
    # multi-host: probe shapes from the cold source directly — a host that
    # does not own client 0 must not pull it into its warm tier
    probe_x = jnp.asarray((pop.probe_client() if multihost
                           else data.clients[0]).x[:2])
    if isinstance(algo, FedGen):
        server = algo.init_server_with_probe(global_params, model,
                                             task.num_classes, probe_x)
    else:
        server = algo.init_server(global_params, model, task.num_classes)

    if rounds == 0:      # empty-history fast path (no uploads, no eval)
        return History(algo.name, [], server["global"], 0.0)

    if task.optimizer == "adam":
        opt = adam(weight_decay=task.weight_decay)
    else:
        opt = sgd(momentum=task.momentum, weight_decay=task.weight_decay)

    n_sample = max(1, int(round(task.participation * data.n_clients)))
    exec_ = executor_lib.get_executor(executor, algo, n_sample, model)
    inner = None
    if isinstance(exec_, executor_lib.AsyncExecutor):
        inner = exec_.resolve_inner(algo, n_sample, model)
    if precompute == "auto":
        effective = inner.name if inner is not None else exec_.name
        precompute = effective != "sequential"
    ctx = executor_lib.RoundContext(
        algo=algo, model=model, opt=opt, lr=task.lr,
        batch_size=task.batch_size, epochs=task.local_epochs,
        max_batches=max_batches_per_client, precompute=bool(precompute),
        client_batched=client_batched)

    if multihost:
        if dp is not None:
            raise NotImplementedError(
                "multi-host placement does not compose with dp= yet")
        # this host's devices must never materialize an unowned slab
        ctx.placement.owns = pop.owned

    if pop is not None:
        # hot tier coherence: warm evictions drop device slabs, slab-store
        # evictions feed population telemetry, pinned set shared
        pop.attach_hot(ctx.placement)
        # lazy per-client state, same tiers (the eager dict below is
        # O(population) host memory — a model copy per client for
        # moon-style states)
        client_states = pop.make_client_states(algo, global_params)
    else:
        client_states = {k: algo.init_client_state(k, global_params)
                         for k in range(data.n_clients)}
    # small server-side validation split for FedGKD-VOTE coefficients
    n_val = min(256, len(data.test_y) // 4)
    val_batch = (jnp.asarray(data.test_x[:n_val]), jnp.asarray(data.test_y[:n_val]))

    injector = None
    policy = None
    if faults is not None:
        from repro.core import systemsim
        from repro.core.server import FaultPolicy
        injector = systemsim.FaultInjector(faults,
                                           systemsim.derive_fault_rng(seed))
        policy = fault_policy if fault_policy is not None else FaultPolicy()
        ctx.telemetry["faults"] = _fault_counters(policy)

    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir=")

    if inner is not None:
        return _run_async(task, algo, data, model, server, ctx, exec_, inner,
                          rng, jrng, seed=seed, rounds=rounds,
                          eval_every=eval_every, verbose=verbose,
                          round_callback=round_callback, dp=dp,
                          n_sample=n_sample, client_states=client_states,
                          val_batch=val_batch, pop=pop,
                          injector=injector, policy=policy,
                          checkpoint_dir=checkpoint_dir,
                          checkpoint_every=checkpoint_every, resume=resume)

    records: list[RoundRecord] = []
    local_acc = 0.0
    uploads: list[dict] = []
    ckpt_host = pop.placement.host_id if multihost else None
    dead_hosts: set = set()     # peers that missed an exchange deadline

    start_round = 0
    if resume:
        from repro.checkpoint import recovery
        hit = recovery.load_latest_state(checkpoint_dir, host=ckpt_host)
        if multihost:
            # coordinated resume: agree on the newest round EVERY host can
            # load (min over hosts — a host that checkpointed further
            # ahead still has the earlier file), restore it, retire this
            # host's stale wave/round exchange files, then confirm all
            # hosts restored the same state before the first round
            from repro.population import placement as placement_lib
            common = placement_lib.resume_barrier(
                pop.placement, hit[2] if hit is not None else None)
            if common is None:
                hit = None
            elif hit is None or hit[2] != common:
                hit = (*recovery.load_state_at(checkpoint_dir, common,
                                               host=ckpt_host), common)
        if hit is not None:
            state, meta, start_round = hit
            if meta.get("algo") not in (None, algo.name):
                raise ValueError(
                    f"resume: checkpoint was written by algo "
                    f"{meta.get('algo')!r}, this run is {algo.name!r}")
            server = state["server"]
            jrng = state["jrng"]
            recovery.restore_rng(rng, state["np_rng"])
            if injector is not None and state.get("fault_rng") is not None:
                recovery.restore_rng(injector.rng, state["fault_rng"])
                if state.get("fault_counters") is not None:
                    injector.counters.update(state["fault_counters"])
                if state.get("fault_telemetry") is not None:
                    ctx.telemetry["faults"].update(state["fault_telemetry"])
            records = [RoundRecord(**d) for d in state["records"]]
            _restore_client_states(client_states, state["client_states"])
        if multihost:
            placement_lib.clear_host_payloads(pop.placement)
            placement_lib.confirm_resume(
                pop.placement, None if hit is None else start_round,
                {"round": None if hit is None else start_round,
                 "algo": algo.name})

    for t in range(start_round, rounds):
        t0 = time.time()
        jrng, krng = jax.random.split(jrng)
        sampled = data.sample_cohort(rng, n_sample)
        payload = algo.round_payload(server, krng)

        cids = [int(k) for k in sampled]
        if multihost and injector is not None:
            # placement-aware fault tolerance: a crashed/deadline-missing
            # HOST is a correlated fault over its whole owned slice;
            # quorum counts surviving hosts' validated uploads and retry
            # re-dispatches the absent slice through the exchange
            uploads, weights, local_losses = _multihost_fault_round(
                exec_, ctx, pop, server, payload, client_states, rng,
                cids, injector, policy, t, dead_hosts)
        elif multihost:
            # train only the owned slice, exchange uploads, aggregate the
            # identical full-cohort update on every host
            uploads, weights, local_losses = _multihost_round(
                ctx, exec_, pop, server["global"], payload, client_states,
                cids, rng, t)
        elif injector is None:
            if pop is not None:
                # the cohort must not thrash the warm tier against itself
                # while the round materializes / trains it
                pop.pin(cids)
            result = exec_.run_round(
                ctx, server["global"], payload,
                [client_states[k] for k in cids],
                [data.clients[k] for k in cids], rng,
                client_ids=cids)
            uploads, weights = result.uploads, result.weights
            local_losses = result.local_losses
            for k, new_state in zip(cids, result.client_states):
                client_states[k] = new_state
        else:
            if pop is not None:
                pop.pin(cids)
            uploads, weights, local_losses = _fault_tolerant_round(
                exec_, ctx, server, payload, client_states, data, rng,
                cids, injector, policy)
        if verbose and t == 0:
            # which route actually ran (the shard_map executor may degrade
            # to vmap on a single device — see RoundContext.telemetry)
            tele = ctx.telemetry
            print(f"[{algo.name}] executor route: "
                  f"{tele.get('route', exec_.name)}"
                  + (f" ({tele['n_devices']} devices, cohort "
                     f"{tele['cohort']} padded to {tele['padded_to']})"
                     if "padded_to" in tele else ""))
        if pop is not None and not multihost:
            pop.unpin(cids)
            ctx.telemetry["population"] = pop.stats()

        if not uploads:
            # every client of the cohort crashed/was rejected through all
            # retries: hold the global fixed rather than aggregate nothing
            ctx.telemetry["faults"]["skipped_rounds"] += 1
        else:
            if dp is not None:
                from repro.core import privacy
                uploads = privacy.privatize_uploads(uploads, server["global"],
                                                    dp, t)
            server = algo.server_update(server, uploads, weights, model,
                                        val_batch, n_clients=data.n_clients)
            if dp is not None:
                from repro.core import privacy
                server["global"] = privacy.noise_aggregate(server["global"],
                                                           dp, len(uploads), t)

        if (t + 1) % eval_every == 0 or t == rounds - 1:
            acc, loss = evaluate(model, server["global"], data.test_x, data.test_y)
        else:
            acc, loss = (records[-1].test_acc, records[-1].test_loss) if records else (0.0, 0.0)
        records.append(RoundRecord(t + 1, acc, loss,
                                   float(np.mean(local_losses)) if local_losses
                                   else 0.0,
                                   time.time() - t0,
                                   sampled=tuple(int(k) for k in sampled)))
        if checkpoint_dir is not None and (
                (t + 1) % checkpoint_every == 0 or t == rounds - 1):
            _save_checkpoint(checkpoint_dir, t + 1, algo, server, jrng, rng,
                             injector, records, client_states, data.n_clients,
                             ftel=ctx.telemetry.get("faults"),
                             host=ckpt_host)
        if round_callback is not None:
            round_callback(t + 1, server, model)
        if verbose:
            print(f"[{algo.name}] round {t+1:3d}/{rounds} "
                  f"acc={acc:.4f} loss={loss:.4f} local={np.mean(local_losses):.4f}")

    # paper Fig.2-style: accuracy of the last trained LOCAL model
    if uploads:
        local_acc, _ = evaluate(model, uploads[-1]["params"],
                                data.test_x, data.test_y)
    if injector is not None:
        ctx.telemetry["faults"].update(injector.counters)
    return History(algo.name, records, server["global"], local_acc,
                   dict(ctx.telemetry))


class _SizeOnly:
    """``materialize_picks`` touches only ``.n`` — this stub lets every
    host pre-draw the full cohort's batch indices from client sizes alone
    (``client_n`` never materializes arrays), keeping the numpy stream in
    lockstep across hosts."""

    __slots__ = ("n",)

    def __init__(self, n: int):
        self.n = int(n)


def _multihost_round(ctx, exec_, pop, global_params, payload, client_states,
                     cids, rng, t):
    """One synchronous round under multi-host placement.

    Every host arrives here with identical ``rng``/``payload``/``cids``
    (the sampler draws in lockstep).  Each host pre-draws batch picks for
    the WHOLE cohort in cohort order — consuming the generator exactly as
    the single-host executors would — then trains only the slice it owns,
    publishes its uploads through the filesystem allgather, and rebuilds
    the full cohort-ordered upload list from every host's payload
    (including its own, re-read from its file, so all hosts aggregate
    byte-identical inputs).  Per-host tier telemetry lands on
    ``telemetry["population"]["hosts"]``, indexed by host id.
    """
    from repro.population import placement as placement_lib

    own_idx = [i for i, c in enumerate(cids) if pop.owned(c)]
    own_cids = [cids[i] for i in own_idx]
    picks_all = [executor_lib.materialize_picks(
        rng, _SizeOnly(pop.client_n(c)), ctx.batch_size, ctx.epochs,
        ctx.max_batches) for c in cids]
    if own_cids:
        pop.pin(own_cids)
        result = exec_.run_round(
            ctx, global_params, payload,
            [client_states[k] for k in own_cids],
            [pop.clients[k] for k in own_cids], rng,
            client_ids=own_cids, picks=[picks_all[i] for i in own_idx])
        pop.unpin(own_cids)
        for k, new_state in zip(own_cids, result.client_states):
            client_states[k] = new_state
        local = {"idx": own_idx, "uploads": result.uploads,
                 "weights": [float(w) for w in result.weights],
                 "losses": [float(v) for v in result.local_losses]}
    else:                       # this host owns nobody this round: it still
        local = {"idx": [], "uploads": [],  # publishes (the barrier) and
                 "weights": [], "losses": []}  # aggregates like the rest
    local["stats"] = dict(pop.stats(),
                          host_rss_mb=placement_lib.peak_rss_mb(),
                          slab=ctx.placement.stats(),
                          exchange=dict(pop.placement.stats))
    gathered = placement_lib.allgather(pop.placement, f"round{t:06d}", local)
    k = len(cids)
    uploads: list = [None] * k
    weights = [0.0] * k
    losses = [0.0] * k
    for host_payload in gathered:
        for j, i in enumerate(host_payload["idx"]):
            uploads[int(i)] = host_payload["uploads"][j]
            weights[int(i)] = float(host_payload["weights"][j])
            losses[int(i)] = float(host_payload["losses"][j])
    missing = [cids[i] for i, u in enumerate(uploads) if u is None]
    if missing:
        raise RuntimeError(
            f"multi-host round {t}: no host owned clients "
            f"{missing[:5]}{'...' if len(missing) > 5 else ''} — the "
            f"placement does not partition the cohort")
    ctx.telemetry["population"] = dict(
        pop.stats(), hosts=[g["stats"] for g in gathered])
    return uploads, weights, losses


def _fault_counters(policy) -> dict:
    """Zeroed ``History.telemetry["faults"]`` schema (injection counters
    from ``FaultInjector.counters`` merge in at the end of the run)."""
    return {"crashes": 0, "timeouts": 0, "corrupt_injected": 0,
            "rejected_nonfinite": 0, "rejected_norm": 0,
            "retries": 0, "redispatches": 0, "backoff_wait": 0.0,
            "quorum_shortfalls": 0, "skipped_rounds": 0,
            "dropped_clients": 0, "quorum_frac": policy.quorum_frac,
            # multi-host placement: injected host crashes (from the
            # injector counters) and real exchange-deadline misses
            "host_crashes": 0, "host_timeouts": 0}


def _fault_tolerant_round(exec_, ctx, server, payload, client_states, data,
                          rng, cids, injector, policy):
    """One synchronous round under fault injection: train the cohort, draw
    per-dispatch faults, gate survivors through ``validate_update``, and
    retry the failed subset with capped exponential backoff until
    ``quorum_frac`` of the cohort survives (or retries run out).

    Returns ``(uploads, weights, local_losses)`` over the survivors, in
    cohort order; survivor client state commits, failed state does not (a
    crashed client's local work is lost, a corrupt client's state is as
    suspect as its update).  With a zero-probability profile every client
    survives on attempt 0 and the round is bit-identical to the unfaulted
    path.
    """
    from repro.core import systemsim
    from repro.core.server import validate_update

    ftel = ctx.telemetry["faults"]
    quorum = max(1, int(np.ceil(policy.quorum_frac * len(cids))))
    uploads: list[dict] = []
    weights: list[float] = []
    losses: list[float] = []
    state_commits: dict = {}
    pending = list(cids)
    attempt = 0
    while pending:
        drawn = [(k, injector.draw()) for k in pending]
        # crash/timeout: the update never arrives, nothing to train for —
        # the simulation skips the wasted local work entirely
        failed = [k for k, f in drawn
                  if f is not None and f[0] in ("crash", "timeout")]
        alive = [(k, f) for k, f in drawn
                 if f is None or f[0] == "corrupt"]
        if alive:
            ids = [k for k, _ in alive]
            result = exec_.run_round(
                ctx, server["global"], payload,
                [client_states[k] for k in ids],
                [data.clients[k] for k in ids], rng, client_ids=ids)
            for i, (k, f) in enumerate(alive):
                up = result.uploads[i]
                if f is not None:
                    up = dict(up, params=systemsim.corrupt_params(
                        up["params"], f[1], injector.profile.huge_scale))
                ok, reason = validate_update(
                    up["params"], server["global"],
                    max_norm_mult=policy.max_norm_mult)
                if ok:
                    uploads.append(up)
                    weights.append(result.weights[i])
                    losses.append(result.local_losses[i])
                    state_commits[k] = result.client_states[i]
                else:
                    ftel["rejected_nonfinite"
                         if reason.startswith("nonfinite")
                         else "rejected_norm"] += 1
                    failed.append(k)
        if len(uploads) >= quorum or not failed \
                or attempt >= policy.max_retries:
            break
        # re-dispatch the failed subset after a capped exponential backoff
        # on the (virtual) clock; each retry re-trains from the same
        # round-frozen payload against the current global
        attempt += 1
        ftel["retries"] += 1
        ftel["redispatches"] += len(failed)
        ftel["backoff_wait"] += policy.backoff(attempt)
        pending = failed
    if len(uploads) < quorum:
        ftel["quorum_shortfalls"] += 1
    for k, s in state_commits.items():
        client_states[k] = s
    return uploads, weights, losses


def _exchange_wave(pop, tag, local, injector, dead_hosts, ftel):
    """Allgather one wave/attempt payload across the placement.

    With fault injection on, a peer missing the deadline degrades to the
    ``missing`` set instead of raising (crash-stop: a dead host never
    publishes, so every survivor resolves the same set) and is never
    polled for again (``dead_hosts`` accumulates across waves); without
    an injector the exchange stays strict — a dead peer is a hard error,
    not a fault to tolerate."""
    from repro.population import placement as placement_lib

    pl = pop.placement
    if injector is None:
        return placement_lib.allgather(pl, tag, local), ()
    gathered, missing = placement_lib.allgather_partial(
        pl, tag, local, skip_wait=dead_hosts)
    new = [h for h in missing if h not in dead_hosts]
    if new:
        ftel["host_timeouts"] += len(new)
        dead_hosts.update(new)
    return gathered, missing


def _multihost_fault_round(exec_, ctx, pop, server, payload, client_states,
                           rng, cids, injector, policy, t, dead_hosts):
    """One synchronous fault-tolerant round under multi-host placement.

    Mirrors ``_fault_tolerant_round`` with the fault model made
    placement-aware: every host replicates the full fault/pick draws (the
    streams stay in lockstep), trains only the alive slice it owns, and
    exchanges the slice results per attempt (tag ``roundTTTTTTaAA``).  A
    crashed HOST — injected via ``FaultProfile.host_crash_prob`` (drawn
    per attempt, one uniform per host in host order) or a real peer
    missing the allgather deadline — is a correlated fault over its
    entire owned slice: those clients fail as a block, quorum counts only
    surviving hosts' validated uploads, and the retry loop re-dispatches
    the absent slice with the usual capped backoff.  Uploads travel CLEAN
    through the exchange with the fault draw replayed on every host:
    ``corrupt_params`` is pure and ``validate_update`` deterministic, so
    survivors accept and reject the very same updates.  With
    ``host_crash_prob == 0`` and no deadline misses this is bit-identical
    to the single-host ``_fault_tolerant_round`` on the same seed.
    """
    from repro.core import systemsim
    from repro.core.server import validate_update
    from repro.population import placement as placement_lib

    pl = pop.placement
    ftel = ctx.telemetry["faults"]
    quorum = max(1, int(np.ceil(policy.quorum_frac * len(cids))))
    uploads: list = []
    weights: list = []
    losses: list = []
    state_commits: dict = {}
    host_stats = None
    pending = list(cids)
    attempt = 0
    while pending:
        crashed = ()
        if injector.profile.host_crash_prob > 0.0:
            crashed = injector.draw_host_crashes(pl.n_hosts)
        drawn = [(k, injector.draw()) for k in pending]
        failed = [k for k, f in drawn
                  if f is not None and f[0] in ("crash", "timeout")]
        alive = [(k, f) for k, f in drawn
                 if f is None or f[0] == "corrupt"]
        alive_ids = [k for k, _ in alive]
        # every host consumes the main stream exactly like the single-host
        # run_round would: full alive-order batch picks from sizes alone
        picks = [executor_lib.materialize_picks(
            rng, _SizeOnly(pop.client_n(k)), ctx.batch_size, ctx.epochs,
            ctx.max_batches) for k in alive_ids]
        own = [(j, k) for j, k in enumerate(alive_ids) if pop.owned(k)]
        local: dict = {"idx": [], "uploads": [], "weights": [],
                       "losses": [], "crashed": pl.host_id in crashed}
        new_states: dict = {}
        if own and not local["crashed"]:
            ids = [k for _, k in own]
            pop.pin(ids)
            result = exec_.run_round(
                ctx, server["global"], payload,
                [client_states[k] for k in ids],
                [pop.clients[k] for k in ids], rng, client_ids=ids,
                picks=[picks[j] for j, _ in own])
            pop.unpin(ids)
            new_states = dict(zip(ids, result.client_states))
            local.update(idx=[j for j, _ in own], uploads=result.uploads,
                         weights=[float(w) for w in result.weights],
                         losses=[float(v) for v in result.local_losses])
        local["stats"] = dict(pop.stats(),
                              host_rss_mb=placement_lib.peak_rss_mb(),
                              slab=ctx.placement.stats(),
                              exchange=dict(pl.stats))
        gathered, _ = _exchange_wave(
            pop, f"round{t:06d}a{attempt:02d}", local, injector,
            dead_hosts, ftel)
        host_stats = [g["stats"] if g is not None else None
                      for g in gathered]
        got = {}
        for g in gathered:
            if g is None or g["crashed"]:
                continue
            for jj, j in enumerate(g["idx"]):
                got[int(j)] = (g["uploads"][jj], float(g["weights"][jj]),
                               float(g["losses"][jj]))
        for j, (k, f) in enumerate(alive):
            hit = got.get(j)
            if hit is None:
                owner = pop.sampler.shard_of(int(k)) % pl.n_hosts
                g = gathered[owner]
                if owner in crashed or g is None or g["crashed"]:
                    failed.append(k)        # correlated host fault
                    continue
                raise RuntimeError(
                    f"multi-host fault round {t}: live host {owner} "
                    f"published no upload for client {k} — the placement "
                    f"does not partition the cohort")
            up, w, lv = hit
            if f is not None:
                up = dict(up, params=systemsim.corrupt_params(
                    up["params"], f[1], injector.profile.huge_scale))
            ok, reason = validate_update(
                up["params"], server["global"],
                max_norm_mult=policy.max_norm_mult)
            if ok:
                uploads.append(up)
                weights.append(w)
                losses.append(lv)
                if k in new_states:
                    state_commits[k] = new_states[k]
            else:
                ftel["rejected_nonfinite" if reason.startswith("nonfinite")
                     else "rejected_norm"] += 1
                failed.append(k)
        if len(uploads) >= quorum or not failed \
                or attempt >= policy.max_retries:
            break
        attempt += 1
        ftel["retries"] += 1
        ftel["redispatches"] += len(failed)
        ftel["backoff_wait"] += policy.backoff(attempt)
        pending = failed
    if len(uploads) < quorum:
        ftel["quorum_shortfalls"] += 1
    for k, s in state_commits.items():
        client_states[k] = s
    ctx.telemetry["population"] = dict(pop.stats(), hosts=host_stats)
    return uploads, weights, losses


def _multihost_wave(ctx, inner, pop, global_params, payload, client_states,
                    cids, rng, tag, slots, injector, dead_hosts, ftel):
    """One async dispatch wave under multi-host placement.

    Every host replicates the whole simulation — sampling, the event
    heap, aggregation, server updates — and this function keeps only the
    TRAINING partitioned: each host pre-draws the FULL wave's batch picks
    (main-stream lockstep), draws the wave's host-crash faults (one
    uniform per host in host order, only when ``host_crash_prob > 0``),
    trains the owned slice it is alive for (in fixed-slot chunks so the
    one compiled body serves every wave), publishes the slice under the
    per-wave exchange ``tag``, and reassembles the full wave.  The
    returned per-client ``(upload, weight, loss, fault)`` list is
    byte-identical on every host (each host re-reads its own payload from
    its exchange file), so the ``SystemSim`` heaps — and therefore the
    virtual clock, pops, redispatches and aggregations — stay in lockstep
    with no further coordination.  A host that crashed (injected) or
    missed the exchange deadline (real death, crash-stop) contributes a
    correlated ``("host_crash", "")`` fault over its whole slice: those
    dispatches still occupy the heap with a ``None`` upload and fall to
    the dead path at buffer fill, where the usual retry/backoff machinery
    re-dispatches them.
    """
    from repro.population import placement as placement_lib

    pl = pop.placement
    crashed = ()
    if injector is not None and injector.profile.host_crash_prob > 0.0:
        crashed = injector.draw_host_crashes(pl.n_hosts)
    picks = [executor_lib.materialize_picks(
        rng, _SizeOnly(pop.client_n(c)), ctx.batch_size, ctx.epochs,
        ctx.max_batches) for c in cids]
    own = [(i, c) for i, c in enumerate(cids) if pop.owned(c)]
    local: dict = {"idx": [], "uploads": [], "weights": [], "losses": [],
                   "crashed": pl.host_id in crashed}
    new_states: dict = {}
    if own and not local["crashed"]:
        pop.pin([c for _, c in own])
        groups = ([own[i:i + slots] for i in range(0, len(own), slots)]
                  if slots is not None else [own])
        for group in groups:
            ids = [c for _, c in group]
            result = inner.run_round(
                ctx, global_params, payload,
                [client_states[k] for k in ids],
                [pop.clients[k] for k in ids], rng, client_ids=ids,
                picks=[picks[i] for i, _ in group])
            new_states.update(zip(ids, result.client_states))
            local["idx"].extend(i for i, _ in group)
            local["uploads"].extend(result.uploads)
            local["weights"].extend(float(w) for w in result.weights)
            local["losses"].extend(float(v) for v in result.local_losses)
    local["stats"] = dict(pop.stats(),
                          host_rss_mb=placement_lib.peak_rss_mb(),
                          slab=ctx.placement.stats(),
                          exchange=dict(pl.stats))
    gathered, _ = _exchange_wave(pop, tag, local, injector, dead_hosts,
                                 ftel)
    # per-client fault draws AFTER training, in wave order — the same
    # fault-stream consumption as the single-host launch path
    per_fault = [injector.draw() if injector is not None else None
                 for _ in cids]
    got = {}
    for g in gathered:
        if g is None or g["crashed"]:
            continue
        for jj, i in enumerate(g["idx"]):
            got[int(i)] = (g["uploads"][jj], float(g["weights"][jj]),
                           float(g["losses"][jj]))
    out = []
    for i, c in enumerate(cids):
        hit = got.get(i)
        if hit is None:
            owner = pop.sampler.shard_of(int(c)) % pl.n_hosts
            g = gathered[owner]
            if owner in crashed or g is None or g["crashed"]:
                out.append((None, 0.0, 0.0, ("host_crash", "")))
                continue
            raise RuntimeError(
                f"multi-host wave {tag}: live host {owner} published no "
                f"upload for client {c} — the placement does not "
                f"partition the wave")
        up, w, lv = hit
        fault = per_fault[i]
        if fault is None and c in new_states:
            # healthy dispatch: commit the owned client's local state
            client_states[c] = new_states[c]
        out.append((up, w, lv, fault))
    host_stats = [g["stats"] if g is not None else None for g in gathered]
    return out, host_stats


def _max_client_n(data) -> int:
    """Largest client example count in the population — the shape bound
    fixed-slot waves pin the compiled round body to.  Population facades
    answer through ``max_client_n()`` without materializing cold shards;
    eager ``FederatedData`` scans its client list."""
    fn = getattr(data, "max_client_n", None)
    if fn is not None:
        return int(fn())
    return int(max(d.n for d in data.clients))


def _snapshot_client_states(client_states, n_clients):
    """Checkpoint payload for per-client algorithm state.  The eager dict
    stores every state by value (O(n_clients) — fine at eager scale); a
    population-tier ``ClientStateStore`` snapshots itself instead (warm
    entries by value, the spill set by reference, nothing at all for
    stateless algorithms) so the checkpoint stays O(touched clients)."""
    if hasattr(client_states, "snapshot"):
        return client_states.snapshot()
    return [client_states[k] for k in range(n_clients)]


def _restore_client_states(client_states, saved):
    if hasattr(client_states, "restore") and isinstance(saved, dict):
        client_states.restore(saved)
        return
    if isinstance(saved, dict):
        raise ValueError(
            "checkpoint holds a population state-store snapshot but this "
            "run uses eager data= — resume with the population= it was "
            "written under")
    for k, s in enumerate(saved):
        client_states[k] = s


def _save_checkpoint(ckpt_dir, rnd, algo, server, jrng, rng, injector,
                     records, client_states, n_clients, ftel=None,
                     extra=None, host=None):
    from repro.checkpoint import recovery
    state = {
        "server": server,
        "jrng": jrng,
        "np_rng": recovery.rng_state(rng),
        "fault_rng": (recovery.rng_state(injector.rng)
                      if injector is not None else None),
        # counters travel with the rng so a resumed run's fault telemetry
        # matches the uninterrupted run, not just the post-resume tail
        "fault_counters": (dict(injector.counters)
                           if injector is not None else None),
        "fault_telemetry": dict(ftel) if ftel is not None else None,
        "records": [dataclasses.asdict(r) for r in records],
        "client_states": _snapshot_client_states(client_states, n_clients),
    }
    if extra:
        state.update(extra)
    recovery.save_run_state(ckpt_dir, rnd, state, meta={"algo": algo.name},
                            host=host)


def _run_async(task: PaperTask, algo: Algorithm, data: FederatedData,
               model: ModelBundle, server: dict,
               ctx: "executor_lib.RoundContext",
               exec_: "executor_lib.AsyncExecutor",
               inner: "executor_lib.ClientExecutor",
               rng: np.random.Generator, jrng, *, seed: int, rounds: int,
               eval_every: int, verbose: bool, round_callback, dp,
               n_sample: int, client_states: dict, val_batch,
               pop=None, injector=None, policy=None,
               checkpoint_dir=None, checkpoint_every: int = 1,
               resume: bool = False) -> History:
    """Buffered-asynchronous rounds on a simulated heterogeneous system.

    Event structure (one History record per AGGREGATION, i.e. per global
    version bump):

      * ``n_sample`` clients are always in flight; each dispatch WAVE
        samples idle clients, trains them through the inner executor
        against the CURRENT global (tagging the uploads with its version),
        and schedules their completions on the virtual clock at
        ``now + local_steps / speed`` (``repro.core.systemsim``);
      * an aggregation consumes the ``B`` earliest completions, weights
        them by data size × staleness scale
        (``repro.core.server.async_aggregation_weights``), applies
        ``server_update``, bumps the version, and redials ``B`` fresh
        clients — the server never waits for the straggler tail;
      * within a buffer, updates aggregate in DISPATCH order (arrival
        order only decides membership): deterministic, and in the
        degenerate homogeneous/full-buffer regime bit-compatible with the
        synchronous executors' cohort order;
      * under the ``"fedgkd"`` staleness scheme stale arrivals are also
        absorbed into the KD teacher buffer (``Algorithm.absorb_stale``)
        so their knowledge distills instead of dragging the average.

    All randomness (speeds, availability phases) comes from a child
    stream of the training seed (``systemsim.derive_rng``); the main
    ``rng``/``jrng`` are consumed exactly like the synchronous loop
    (sample, then materialize), which is what makes the equivalence and
    determinism suites exact.

    With fault injection (``injector``/``policy`` from
    ``run_federated(faults=)``) each dispatch additionally draws a fault
    from the dedicated fault stream: crashed/timed-out/invalid
    completions are skipped by the buffer fill (which keeps draining the
    heap until it holds ``B`` VALIDATED updates), the failed client is
    re-dispatched against the current global after a capped exponential
    backoff on the simulated clock (dropped from the fleet after
    ``max_retries`` consecutive failures), and the post-aggregation
    refill tops the fleet back up to ``n_sample`` in flight.

    Fixed-slot waves + pipelining (``AsyncExecutor(wave_slots=,
    pipelined=)``): with fixed slots every dispatch wave pads to the
    buffer size B through the phantom-client masking machinery — refills
    ARE B clients, redispatches pad 1 → B, and the initial ``n_sample``
    wave trains as ceil(n_sample/B) chunks of the same B-slot body — so
    exactly ONE compiled round body serves the whole run
    (``telemetry["compile_count"]``).  Pipelined mode defers every host
    sync to the aggregation: the inner executor returns on-device losses,
    the refill wave's sampling/materialization/teacher-precompute dispatch
    BEFORE the round's eval forces, and ``jax.block_until_ready`` runs
    only on the buffer being aggregated — wave N+1's host+device prologue
    overlaps wave N's training.  Both knobs change scheduling only: the
    aggregated numbers are identical to the single-stream variable-wave
    path (the equivalence tests pin fixed-vs-variable bit-for-bit at zero
    faults and pipelined-vs-single-stream < 1e-5).

    ``checkpoint_dir=``/``resume=`` compose with all of the above: each
    aggregation checkpoints the full run state INCLUDING the simulator
    (clock, event heap with its tagged in-flight upload pytrees, dispatch
    sequence) and the per-client retry counters, so a run killed mid-wave
    resumes into the exact wave it died in and replays the uninterrupted
    history bit-for-bit — faults included (corruption is applied at
    buffer-fill time, never inside the heap, so the snapshot only ever
    holds finite leaves).
    """
    from repro.core import systemsim
    from repro.core.server import async_aggregation_weights

    b = exec_.buffer_size if exec_.buffer_size is not None else n_sample
    if not (1 <= b <= n_sample):
        raise ValueError(
            f"async buffer_size must be in [1, cohort={n_sample}]: a larger "
            f"buffer than the in-flight fleet can never fill (got {b})")
    sim = systemsim.SystemSim(
        data.n_clients, profile=exec_.profile,
        availability=exec_.availability, rng=systemsim.derive_rng(seed),
        base_step_time=exec_.base_step_time)

    def client_work(n: int) -> int:
        steps = pipeline_num_batches(n, ctx.batch_size, ctx.epochs)
        if ctx.max_batches is not None:
            steps = min(steps, ctx.max_batches)
        return steps

    # local work priced lazily from client SIZES (``client_n`` never
    # materializes arrays), memoized per sampled client — the eager
    # per-client list this replaces was O(population) host work
    work_memo: dict[int, int] = {}

    def work_of(k: int) -> int:
        w = work_memo.get(k)
        if w is None:
            w = work_memo[k] = client_work(data.client_n(k))
        return w

    # fixed-slot wave geometry: pin the batched round body's shapes to
    # population-wide maxima (client size -> steps/batch/rows are all
    # monotone in n, so the largest client bounds every wave) and the
    # client axis to the buffer size — one compiled body for the run
    slots = exec_.resolve_wave_slots(b, inner)
    if slots is not None:
        n_max = _max_client_n(data)
        ctx.wave_slots = slots
        ctx.pad_steps = client_work(n_max)
        ctx.pad_batch = min(ctx.batch_size, n_max)
        ctx.pad_rows = n_max
    # pipelined mode: batched inners return on-device losses (forced only
    # at aggregation, below) instead of syncing the host per wave.
    # Multi-host forces the per-wave sync back on: the wave's uploads
    # cross the filesystem exchange as host arrays immediately, so there
    # is nothing left to defer
    multihost = pop is not None and getattr(pop, "multihost", False)
    ctx.deferred = bool(exec_.pipelined and inner.name != "sequential"
                        and not multihost)

    # in-flight ids are the SMALL set (≤ n_sample); sampling excludes them
    # instead of enumerating the O(population) idle complement — for flat
    # data ``sample_cohort(exclude=...)`` reproduces the historical
    # sorted-idle-array draw bit for bit
    in_flight: set[int] = set()
    version = 0
    stale_absorbed = 0
    max_stale = 0.0
    records: list[RoundRecord] = []
    uploads: list[dict] = []
    ftel = ctx.telemetry.get("faults")
    fail_count: dict[int, int] = {}     # consecutive failures per client
    dead_hosts: set = set()     # peers that missed an exchange deadline
    wave_seq = 0    # per-wave exchange tag counter, in lockstep across
    # hosts because every host replays the identical dispatch sequence
    mh_stats: dict = {"hosts": None}    # latest per-host tier telemetry
    ckpt_host = pop.placement.host_id if multihost else None

    def owned_only(ids):
        """Pin/unpin only this host's slice under placement (pure set ops
        either way, but unowned ids must not clutter the pinned set)."""
        return [k for k in ids if pop.owned(k)] if multihost else ids

    def launch(cids: "list[int]", krng, delay: float = 0.0) -> None:
        """Train ``cids`` against the current global and schedule their
        completions (with per-dispatch fault draws when injection is on:
        a faulted dispatch still occupies the heap — inflated by the
        timeout factor for the timeout tail — but its tag marks it dead;
        corruption is applied at buffer-FILL time from the tag, so the
        heap itself only ever holds finite uploads and stays
        checkpointable through ``io.save_pytree``'s non-finite gate).

        In fixed-slot mode the wave trains in chunks of ``slots`` clients
        so every inner call runs the one compiled B-slot body; sampling
        (one ``sample_cohort`` per wave) and the sim dispatch sequence
        are untouched — chunking is invisible to both.

        Under multi-host placement the wave detours through
        ``_multihost_wave``: each host trains only its owned slice and the
        full wave reassembles from the per-wave exchange, but the sim
        dispatch sequence below is identical on every host — the heaps
        (and so the clock, the pops and the aggregations) never diverge."""
        nonlocal wave_seq
        payload = algo.round_payload(server, krng)
        if multihost:
            tag = f"wave{wave_seq:09d}"
            wave_seq += 1
            results, mh_stats["hosts"] = _multihost_wave(
                ctx, inner, pop, server["global"], payload, client_states,
                cids, rng, tag, slots, injector, dead_hosts, ftel)
            for k, (up, w, lv, fault) in zip(cids, results):
                slowdown = (injector.profile.timeout_factor
                            if fault is not None and fault[0] == "timeout"
                            else 1.0)
                in_flight.add(k)
                sim.dispatch(k, work_of(k), tag={
                    "upload": up, "weight": w, "loss": lv,
                    "version": version, "fault": fault},
                    delay=delay, slowdown=slowdown)
            return
        if pop is not None:
            # in-flight clients keep their warm shard / device slab /
            # state-tier entries until their completions aggregate
            pop.pin(cids)
        groups = ([cids[i:i + slots] for i in range(0, len(cids), slots)]
                  if slots is not None else [cids])
        for group in groups:
            result = inner.run_round(
                ctx, server["global"], payload,
                [client_states[k] for k in group],
                [data.clients[k] for k in group], rng, client_ids=group)
            for i, k in enumerate(group):
                fault = injector.draw() if injector is not None else None
                if fault is None:
                    # a failed client's local work is lost: only healthy
                    # dispatches commit their state update
                    client_states[k] = result.client_states[i]
                slowdown = (injector.profile.timeout_factor
                            if fault is not None and fault[0] == "timeout"
                            else 1.0)
                in_flight.add(k)
                sim.dispatch(k, work_of(k), tag={
                    "upload": result.uploads[i],
                    "weight": result.weights[i],
                    "loss": result.local_losses[i], "version": version,
                    "fault": fault}, delay=delay, slowdown=slowdown)

    def dispatch_wave(k_count: int) -> None:
        nonlocal jrng
        if k_count == 0:
            return
        jrng, krng = jax.random.split(jrng)
        # with an EMPTY in-flight set this is the synchronous loop's exact
        # rng.choice call — a seed draws the same cohorts here as in the
        # sync loop; with clients in flight the excluded draw reproduces
        # the historical sorted-idle-array indexing bit for bit
        sampled = data.sample_cohort(rng, k_count, exclude=in_flight)
        launch([int(k) for k in sampled], krng)

    def redispatch(k: int, delay: float) -> None:
        nonlocal jrng
        jrng, krng = jax.random.split(jrng)
        launch([k], krng, delay=delay)

    def fill_buffer() -> list:
        """Drain the heap until it yields ``b`` VALIDATED completions —
        dead (crash/timeout) and rejected updates are skipped, their
        clients re-dispatched with capped exponential backoff (dropped
        from the fleet after ``max_retries`` consecutive failures).  May
        return fewer than ``b`` (even zero) when the whole fleet fails
        out."""
        from repro.core.server import validate_update

        out: list = []
        while len(out) < b and sim.in_flight > 0:
            c = sim.pop()
            fault = c.tag.get("fault")
            if fault is not None and fault[0] == "corrupt":
                # corruption is applied HERE, not at dispatch: the heap
                # only ever holds the clean finite upload plus the fault
                # tag (so checkpoints of in-flight state pass io.py's
                # non-finite gate), and ``corrupt_params`` is pure, so a
                # restored heap replays the same corrupted bytes
                up = c.tag["upload"]
                c.tag["upload"] = dict(up, params=systemsim.corrupt_params(
                    up["params"], fault[1], injector.profile.huge_scale))
            if fault is None or fault[0] == "corrupt":
                ok, reason = validate_update(
                    c.tag["upload"]["params"], server["global"],
                    max_norm_mult=policy.max_norm_mult)
                if ok:
                    out.append(c)
                    fail_count.pop(c.client, None)
                    continue
                ftel["rejected_nonfinite"
                     if reason.startswith("nonfinite")
                     else "rejected_norm"] += 1
            # dead completion: free the slot, retry or drop the client
            in_flight.discard(c.client)
            if pop is not None:
                pop.unpin(owned_only([c.client]))
            fails = fail_count.get(c.client, 0) + 1
            fail_count[c.client] = fails
            if fails <= policy.max_retries:
                delay = policy.backoff(fails)
                ftel["redispatches"] += 1
                ftel["retries"] += 1
                ftel["backoff_wait"] += delay
                redispatch(c.client, delay)
            else:
                ftel["dropped_clients"] += 1
                fail_count.pop(c.client, None)
        return out

    def refill() -> None:
        if injector is None:
            dispatch_wave(b)
        else:
            # permanently dropped clients shrink the fleet below
            # n_sample: top back up (bounded by the idle population)
            want = min(n_sample - len(in_flight),
                       data.n_clients - len(in_flight))
            dispatch_wave(max(0, want))

    def save_ckpt(rnd: int) -> None:
        """Checkpoint the FULL async run state.  Must run AFTER the
        round's refill: the heap snapshot has to contain the wave the
        uninterrupted run would carry into round ``rnd + 1``, or resume
        would aggregate from an under-filled fleet."""
        if checkpoint_dir is None or (
                rnd % checkpoint_every != 0 and rnd != rounds):
            return
        _save_checkpoint(
            checkpoint_dir, rnd, algo, server, jrng, rng, injector,
            records, client_states, data.n_clients, ftel=ftel,
            extra={"sim": sim.state(),
                   "in_flight": sorted(in_flight),
                   "version": version,
                   "stale_absorbed": stale_absorbed,
                   "max_stale": max_stale,
                   "wave_seq": wave_seq,
                   "fail_count": sorted(fail_count.items())},
            host=ckpt_host)

    start_round = 0
    if resume:
        from repro.checkpoint import recovery
        hit = recovery.load_latest_state(checkpoint_dir, host=ckpt_host)
        if multihost:
            # coordinated resume: agree on the newest aggregation EVERY
            # host can load (min over hosts — a host that checkpointed
            # further ahead still has the earlier file), restore it,
            # retire this host's stale wave exchange files, then confirm
            # all hosts restored the same {round, version} before the
            # first wave runs
            from repro.population import placement as placement_lib
            common = placement_lib.resume_barrier(
                pop.placement, hit[2] if hit is not None else None)
            if common is None:
                hit = None
            elif hit is None or hit[2] != common:
                hit = (*recovery.load_state_at(checkpoint_dir, common,
                                               host=ckpt_host), common)
        if hit is not None:
            state, meta, start_round = hit
            if meta.get("algo") not in (None, algo.name):
                raise ValueError(
                    f"resume: checkpoint was written by algo "
                    f"{meta.get('algo')!r}, this run is {algo.name!r}")
            server = state["server"]
            jrng = state["jrng"]
            recovery.restore_rng(rng, state["np_rng"])
            if injector is not None and state.get("fault_rng") is not None:
                recovery.restore_rng(injector.rng, state["fault_rng"])
                if state.get("fault_counters") is not None:
                    injector.counters.update(state["fault_counters"])
                if state.get("fault_telemetry") is not None:
                    ftel.update(state["fault_telemetry"])
            records = [RoundRecord(**d) for d in state["records"]]
            _restore_client_states(client_states, state["client_states"])
            sim.restore(state["sim"])
            in_flight = set(int(k) for k in state["in_flight"])
            version = int(state["version"])
            stale_absorbed = int(state["stale_absorbed"])
            max_stale = float(state["max_stale"])
            fail_count.clear()
            fail_count.update({int(k): int(v)
                               for k, v in state["fail_count"]})
            wave_seq = int(state.get("wave_seq", 0))
            if pop is not None and in_flight:
                # restored in-flight clients must hold their warm/slab
                # pins exactly as they did when the checkpoint was cut
                pop.pin(owned_only(sorted(in_flight)))
        if multihost:
            placement_lib.clear_host_payloads(pop.placement)
            placement_lib.confirm_resume(
                pop.placement, None if hit is None else start_round,
                {"round": None if hit is None else start_round,
                 "version": version, "algo": algo.name})

    # with checkpointing on, the FINAL round refills too: its checkpoint
    # then matches the one an uninterrupted longer run writes at the same
    # round, so a finished run can be EXTENDED (resume with more rounds)
    # bit-identically, not just recovered from a kill
    def wants_refill(t: int) -> bool:
        return t < rounds - 1 or checkpoint_dir is not None

    if start_round == 0:
        dispatch_wave(n_sample)
    for t in range(start_round, rounds):
        t0 = time.time()
        if injector is None:
            completions = sim.pop_batch(b)
        else:
            completions = fill_buffer()
            if not completions:
                # the whole fleet failed out this aggregation window:
                # hold the global, record the skipped event, redial
                ftel["skipped_rounds"] += 1
                acc, loss = ((records[-1].test_acc, records[-1].test_loss)
                             if records else
                             evaluate(model, server["global"],
                                      data.test_x, data.test_y))
                records.append(RoundRecord(
                    t + 1, acc, loss, 0.0, time.time() - t0,
                    sim_time=sim.now, version=version))
                if wants_refill(t):
                    dispatch_wave(min(b, data.n_clients - len(in_flight)))
                save_ckpt(t + 1)
                continue
        # canonical aggregation order: dispatch sequence (see docstring)
        completions.sort(key=lambda c: c.seq)
        staleness = [version - c.tag["version"] for c in completions]
        max_stale = max(max_stale, float(max(staleness)))
        agg_uploads = [c.tag["upload"] for c in completions]
        data_weights = [c.tag["weight"] for c in completions]
        weights = async_aggregation_weights(
            data_weights, staleness, exec_.staleness, a=exec_.staleness_a,
            cutoff=exec_.staleness_cutoff, normalize=False)
        # deferred (pipelined) completions carry on-device losses; this
        # float() + the block_until_ready below are the round's ONLY
        # host syncs — everything still in flight stays in flight
        local_losses = [float(c.tag["loss"]) for c in completions]
        if ctx.deferred:
            jax.block_until_ready(agg_uploads)
        if verbose and t == 0:
            tele = ctx.telemetry
            print(f"[{algo.name}] executor route: async/"
                  f"{tele.get('route', inner.name)} (buffer B={b}, "
                  f"staleness={exec_.staleness}, "
                  f"profile={sim.profile.kind})")

        uploads = agg_uploads
        if dp is not None:
            from repro.core import privacy
            uploads = privacy.privatize_uploads(uploads, server["global"],
                                                dp, t)
        server = algo.server_update(server, uploads, weights, model,
                                    val_batch, n_clients=data.n_clients)
        if dp is not None:
            from repro.core import privacy
            server["global"] = privacy.noise_aggregate(server["global"], dp,
                                                       len(uploads), t)
        if exec_.staleness == "fedgkd":
            n_stale = sum(1 for s in staleness if s > 0)
            if n_stale:
                stale_absorbed += n_stale
                server = algo.absorb_stale(server, uploads, staleness,
                                           data_weights, model=model,
                                           val_batch=val_batch)
        version += 1
        for c in completions:
            in_flight.discard(c.client)
        if pop is not None:
            pop.unpin(owned_only([c.client for c in completions]))
            ctx.telemetry["population"] = (
                dict(pop.stats(), hosts=mh_stats["hosts"]) if multihost
                else pop.stats())

        refilled = False
        if ctx.deferred and wants_refill(t):
            # pipelining: dispatch wave N+1 (sampling, slab gather,
            # teacher precompute, device launch) BEFORE this round's
            # eval forces the host — the refill's host prologue and its
            # device work overlap the eval and the wait for the next
            # buffer.  Eval consumes no rng, so hoisting the dispatch
            # past it leaves the sampled history untouched.
            refill()
            refilled = True
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            acc, loss = evaluate(model, server["global"], data.test_x,
                                 data.test_y)
        else:
            acc, loss = ((records[-1].test_acc, records[-1].test_loss)
                         if records else (0.0, 0.0))
        records.append(RoundRecord(
            t + 1, acc, loss, float(np.mean(local_losses)),
            time.time() - t0, sim_time=sim.now, version=version,
            mean_staleness=float(np.mean(staleness)),
            sampled=tuple(c.client for c in completions)))
        if wants_refill(t) and not refilled:
            refill()
        save_ckpt(t + 1)
        if round_callback is not None:
            round_callback(t + 1, server, model)
        if verbose:
            print(f"[{algo.name}] agg {t+1:3d}/{rounds} v{version} "
                  f"acc={acc:.4f} loss={loss:.4f} "
                  f"local={np.mean(local_losses):.4f} "
                  f"sim_t={sim.now:.1f} stale={np.mean(staleness):.2f}")

    if pop is not None and in_flight:
        # clients still in flight when the run ends would stay pinned —
        # a reused Population would then exempt them from eviction forever
        pop.unpin(owned_only(in_flight))
        ctx.telemetry["population"] = (
            dict(pop.stats(), hosts=mh_stats["hosts"]) if multihost
            else pop.stats())
    ctx.telemetry.update(
        route="async", inner_route=ctx.telemetry.get("route", inner.name),
        buffer_size=b, staleness_scheme=exec_.staleness,
        aggregations=rounds, final_version=version,
        stale_absorbed=stale_absorbed,
        mean_staleness=float(np.mean([r.mean_staleness for r in records])),
        max_staleness=max_stale, sim=sim.stats())
    if injector is not None:
        ftel.update(injector.counters)

    local_acc = 0.0
    if uploads:
        local_acc, _ = evaluate(model, uploads[-1]["params"],
                                data.test_x, data.test_y)
    return History(algo.name, records, server["global"], local_acc,
                   dict(ctx.telemetry))


def make_federated_data(task: PaperTask, alpha: float, seed: int = 0,
                        n_test: int = 1000) -> FederatedData:
    from repro.data.synthetic import make_task_data
    xtr, ytr, xte, yte = make_task_data(task, task.train_size, n_test, seed=seed)
    return FederatedData.from_arrays(xtr, ytr, xte, yte,
                                     n_clients=task.n_clients, alpha=alpha,
                                     seed=seed)
