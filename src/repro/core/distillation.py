"""Knowledge-distillation losses and global-model ensembling (paper Eq. 3-5).

The KD regularizer is ``(γ/2)·E_x[ KL( h(w_teacher; x) ‖ h(w; x) ) ]`` —
teacher distribution first (forward KL), matching Eq. (3).  ``γ_m`` weights
for FedGKD-VOTE follow the paper's softmax-of-validation-loss rule.

``kl_divergence`` (and therefore ``kd_loss_kl``, the KD term in every hot
loss) executes through the fused Pallas kernel
``repro.kernels.kd_kl.ops.kd_kl_loss`` on TPU — one custom-VJP kernel pass
instead of three materialized softmaxes.  Off-TPU it runs the pure-jnp
oracle (identical math); gradients flow ONLY to the student either way,
which matches every call site (teachers are frozen payload constants).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kd_kl import ops as _kd_ops


def kl_divergence(teacher_logits: jax.Array, student_logits: jax.Array,
                  temperature: float = 1.0,
                  use_pallas: bool | None = None) -> jax.Array:
    """Per-example KL(p_T ‖ p_S)·T². Shapes (..., C) -> (...).

    ``use_pallas=None`` auto-selects the fused Pallas kernel on TPU and the
    jnp oracle elsewhere; pass True/False to force a path (tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    return _kd_ops.kd_kl_loss(teacher_logits, student_logits,
                              temperature=temperature, use_pallas=use_pallas)


def masked_mean(values: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Weighted mean over ``values`` (any shape); ``mask is None`` == plain
    mean.  Zero-weight entries contribute nothing to value or gradient, so
    padded examples in a batched (vmap) client step are exact no-ops."""
    if mask is None:
        return jnp.mean(values)
    w = mask.astype(jnp.float32)
    return jnp.sum(values * w) / jnp.maximum(1.0, jnp.sum(w))


def masked_mean_per_client(values: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Row of ``masked_mean``s over a stacked cohort: values ``(K, B)`` ->
    ``(K,)``.  Client k's entry equals ``masked_mean(values[k], mask[k])``
    exactly, so a sum over the K axis is the batched executors' total loss
    whose gradient w.r.t. client-stacked params is the per-client
    gradients (parameters are disjoint across clients)."""
    if mask is None:
        return jnp.mean(values, axis=-1)
    w = mask.astype(jnp.float32)
    return jnp.sum(values * w, axis=-1) / jnp.maximum(1.0, jnp.sum(w, axis=-1))


def cross_entropy_per_client(logits: jax.Array, labels: jax.Array,
                             ignore_index: int = -1,
                             mask: jax.Array | None = None) -> jax.Array:
    """Per-client masked-mean CE: logits ``(K, B, C)`` -> ``(K,)``; each
    entry matches ``cross_entropy(logits[k], labels[k], mask=mask[k])``
    to the op (same log-softmax, same masked sum, negated last)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = (labels != ignore_index).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask.astype(jnp.float32)
    safe = jnp.where(labels != ignore_index, labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * valid, axis=-1) / jnp.maximum(
        1.0, jnp.sum(valid, axis=-1))


def param_sq_dist_per_client(stacked, anchor) -> jax.Array:
    """‖w_k − anchor‖² per client: leaves ``(K, ...)`` against the shared
    anchor ``(...)`` -> ``(K,)`` (FedProx/FedDyn proximal terms on the
    client-stacked route)."""
    total = 0.0
    for s, a in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(anchor)):
        d = s.astype(jnp.float32) - a.astype(jnp.float32)[None]
        total = total + jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
    return total


def kd_loss_kl(teacher_logits, student_logits, gamma: float,
               temperature: float = 1.0, mask=None,
               use_pallas: bool | None = None) -> jax.Array:
    """Paper Eq.(3) KD term: (γ/2)·mean KL (fused kernel on TPU)."""
    return 0.5 * gamma * masked_mean(
        kl_divergence(teacher_logits, student_logits, temperature,
                      use_pallas=use_pallas), mask)


def kd_loss_mse(teacher_logits, student_logits, gamma: float,
                mask=None) -> jax.Array:
    """Table 9 ablation: MSE over logits instead of KL."""
    d = (teacher_logits.astype(jnp.float32)
         - student_logits.astype(jnp.float32))
    return 0.5 * gamma * masked_mean(jnp.sum(jnp.square(d), axis=-1), mask)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -1, mask: jax.Array | None = None) -> jax.Array:
    """Mean CE with optional ignore label (used to mask frontend positions)
    and optional per-example weights (executor padding mask)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = (labels != ignore_index).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask.astype(jnp.float32)
    safe = jnp.where(labels != ignore_index, labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * valid) / jnp.maximum(1.0, jnp.sum(valid))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# global-model ensembling (server side)
# ---------------------------------------------------------------------------

def ensemble_average(params_list: list) -> dict:
    """FedGKD fused teacher: plain weight-space mean of the buffered models
    (Polyak-style, Eq. w̄_t = (1/M)·Σ w_{t-m+1})."""
    m = len(params_list)
    return jax.tree_util.tree_map(
        lambda *xs: sum(x.astype(jnp.float32) for x in xs) / m, *params_list)


def vote_coefficients(val_losses: list[float], lam: float = 0.1,
                      beta: float | None = None) -> list[float]:
    """FedGKD-VOTE γ_m/2 = λ · softmax(-L_m/β); β defaults to 1/M (paper)."""
    m = len(val_losses)
    beta = beta if beta is not None else 1.0 / m
    l = jnp.asarray(val_losses, jnp.float32)
    w = jax.nn.softmax(-l / beta)
    return [2.0 * lam * float(x) for x in w]  # returns γ_m (the full coefficient)


def param_sq_dist(a, b) -> jax.Array:
    """‖a − b‖² over pytrees (FedProx proximal term)."""
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32) - y.astype(jnp.float32)))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))
