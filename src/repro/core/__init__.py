"""FL algorithms: FedGKD (the paper's contribution) + all compared baselines.

Public surface:
    from repro.core import algorithms, executor, fl_loop, distillation

    algo = algorithms.make("fedgkd", gamma=0.2, buffer_m=5)
    history = fl_loop.run_federated(task, algo, data, executor="vmap")

``run_federated(..., executor=)`` selects the pluggable client-execution
strategy (repro.core.executor):

    "sequential"  reference semantics — one jitted lax.scan per client
    "vmap"        the whole sampled cohort trains in ONE jitted XLA call
                  (stacked/padded batches, masked ragged clients)
    "shard_map"   multi-device: the cohort sharded over a ("clients",)
                  device mesh, client shards device-resident across
                  rounds, non-dividing cohorts padded with masked
                  phantom clients
    "auto"        (default) vmap when both the algorithm and the model
                  support batched execution, else sequential

Algorithms implement a small pure pytree-in/pytree-out interface —
``loss_fn`` / ``client_finalize`` / ``update_client_state`` — which every
executor may trace once and vmap/shard over clients; see
``algorithms.Algorithm`` for the contract.
"""
from repro.core import (algorithms, client, distillation, executor, fl_loop,  # noqa: F401
                        modelzoo, server)
