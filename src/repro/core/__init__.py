"""FL algorithms: FedGKD (the paper's contribution) + all compared baselines.

Public surface:
    from repro.core import algorithms, fl_loop, distillation
    algo = algorithms.make("fedgkd", gamma=0.2, buffer_m=5)
    history = fl_loop.run_federated(task, algo, data, ...)
"""
from repro.core import distillation, server, client, algorithms, fl_loop, modelzoo  # noqa: F401
