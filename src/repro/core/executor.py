"""Pluggable client execution: how one round's sampled clients are trained.

The FL loop (``repro.core.fl_loop``) is algorithm-agnostic; this module makes
it *execution*-agnostic too.  A ``ClientExecutor`` consumes the round inputs
(global params, broadcast payload, per-client states and data shards) and
produces the round outputs (uploads, weights, local losses, new states) —
how the clients actually run is its business:

    SequentialExecutor   one jitted lax.scan per client, Python loop over
                         clients — the reference semantics
    VmapExecutor         pad/stack the sampled clients' batches and vmap the
                         SAME scan so one jitted XLA call trains every
                         client in parallel
    ShardMapExecutor     VmapExecutor whose stacked computation is routed
                         through a "clients" device mesh with shard_map
                         (the repro/launch path); falls back to plain vmap
                         when the device count does not divide the cohort

All three consume identical materialized batches (one shared host-RNG draw,
same order as the historical per-client iterator), so sequential and vmap
outputs agree to float-associativity (~1e-6 on the paper's small models).

Masking rules for ragged clients (see ``repro.core.client``):
  * every batch within a client has a uniform size ``min(B, n_k)``; across
    clients batches are zero-padded to the cohort max with a per-example
    mask that zero-weights pads inside the loss — exact, not approximate;
  * clients with fewer steps than the cohort max get whole padded steps
    masked out as identities on (params, opt_state).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client as client_lib
from repro.core.algorithms import Algorithm
from repro.core.modelzoo import ModelBundle
from repro.data.pipeline import ClientData
from repro.optim import Optimizer


# ---------------------------------------------------------------------------
# round inputs/outputs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RoundContext:
    """Everything fixed across rounds that an executor needs."""
    algo: Algorithm
    model: ModelBundle
    opt: Optimizer
    lr: float
    batch_size: int
    epochs: int
    max_batches: Optional[int] = None

    def __post_init__(self):
        loss_fn = self.algo.loss_fn(self.model)
        # scan-based whole-client pass (vmap/shard_map paths)
        self.local_update = client_lib.make_local_update(loss_fn, self.opt)
        # per-batch step (sequential path: compiles once per batch SHAPE
        # rather than once per (steps, batch) pair like the scan would)
        self.step = client_lib.make_step(loss_fn, self.opt, jit=True)
        # jitted-artifact cache owned by THIS context (executors must not
        # key a shared cache on id(ctx): the id can be reused after gc and
        # serve another algorithm's compiled round function)
        self.jit_cache: dict = {}
        # hooks left at the Algorithm defaults are no-ops — executors skip
        # the (host + dispatch) work of calling them entirely
        cls = type(self.algo)
        self.has_finalize = cls.client_finalize is not Algorithm.client_finalize
        self.has_state_update = (
            cls.update_client_state is not Algorithm.update_client_state)


@dataclasses.dataclass
class RoundResult:
    """Stacked-back-to-lists round outputs; shapes match the historical
    sequential loop so server_update / privacy / History are untouched."""
    uploads: list[dict]
    weights: list[float]
    local_losses: list[float]
    client_states: list[Any]


@runtime_checkable
class ClientExecutor(Protocol):
    name: str

    def run_round(self, ctx: RoundContext, global_params: Any, payload: Any,
                  client_states: list[Any], client_data: list[ClientData],
                  rng: np.random.Generator) -> RoundResult:
        ...


# ---------------------------------------------------------------------------
# batch materialization (shared by all executors)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MaterializedClient:
    xs: np.ndarray      # (S_k, bs_k, ...)
    ys: np.ndarray      # (S_k, bs_k)
    n: int              # true example count (aggregation weight)


def materialize_client(rng: np.random.Generator, data: ClientData,
                       batch_size: int, epochs: int,
                       max_batches: Optional[int] = None) -> MaterializedClient:
    """Draw the client's epoch batches up front.

    Consumes ``rng`` exactly like the historical lazy ``batch_iterator``
    (one permutation per *started* epoch, partial batches wrap-padded), so
    a given seed yields the same batch sequence under every executor.
    """
    n = data.n
    bs = min(batch_size, n)
    picks: list[np.ndarray] = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, bs):
            idx = order[i:i + bs]
            if len(idx) < bs:               # wrap the final partial batch
                idx = np.concatenate([idx, order[: bs - len(idx)]])
            picks.append(idx)
            if max_batches is not None and len(picks) >= max_batches:
                break
        if max_batches is not None and len(picks) >= max_batches:
            break
    sel = np.stack(picks)                   # (S_k, bs_k)
    return MaterializedClient(data.x[sel], data.y[sel], n)


def _pad_and_stack(mats: list[MaterializedClient]):
    """(K, S, B, ...) arrays + example mask (K, S, B) + step mask (K, S)."""
    S = max(m.xs.shape[0] for m in mats)
    B = max(m.xs.shape[1] for m in mats)
    k = len(mats)
    feat = mats[0].xs.shape[2:]
    xs = np.zeros((k, S, B) + feat, mats[0].xs.dtype)
    ys = np.zeros((k, S, B), mats[0].ys.dtype)
    ex_mask = np.zeros((k, S, B), np.float32)
    step_mask = np.zeros((k, S), bool)
    for i, m in enumerate(mats):
        s, b = m.xs.shape[:2]
        xs[i, :s, :b] = m.xs
        ys[i, :s, :b] = m.ys
        ex_mask[i, :s, :b] = 1.0
        step_mask[i, :s] = True
    return (jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ex_mask),
            jnp.asarray(step_mask))


def _pad_full_data(client_data: list[ClientData]):
    """Stack each client's FULL shard to (K, N_max, ...) + mask for the
    vmapped ``client_finalize`` hook."""
    n_max = max(d.n for d in client_data)
    k = len(client_data)
    feat = client_data[0].x.shape[1:]
    xs = np.zeros((k, n_max) + feat, client_data[0].x.dtype)
    ys = np.zeros((k, n_max), client_data[0].y.dtype)
    mask = np.zeros((k, n_max), np.float32)
    for i, d in enumerate(client_data):
        xs[i, :d.n] = d.x
        ys[i, :d.n] = d.y
        mask[i, :d.n] = 1.0
    return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)


def tree_stack(trees: list[Any]) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree: Any, k: int) -> list[Any]:
    return [jax.tree_util.tree_map(lambda l: l[i], tree) for i in range(k)]


@functools.partial(jax.jit, static_argnums=1)
def _tree_unstack_jit(tree: Any, k: int) -> list[Any]:
    """tree_unstack as ONE dispatch (eager per-leaf slicing costs ~K·L tiny
    device ops per round, which dominates small-model rounds)."""
    return tree_unstack(tree, k)


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class SequentialExecutor:
    """Reference implementation: clients one at a time, one jitted step per
    batch (the historical loop — no padding, no masks)."""

    name = "sequential"

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng) -> RoundResult:
        uploads, weights, losses, new_states = [], [], [], []
        for state, cdata in zip(client_states, client_data):
            mat = materialize_client(rng, cdata, ctx.batch_size, ctx.epochs,
                                     ctx.max_batches)
            params, opt_state = global_params, ctx.opt.init(global_params)
            step_losses = []
            for s in range(mat.xs.shape[0]):
                params, opt_state, loss, _ = ctx.step(
                    params, opt_state, payload, state,
                    jnp.asarray(mat.xs[s]), jnp.asarray(mat.ys[s]), None,
                    ctx.lr)
                step_losses.append(float(loss))
            extras = {}
            if ctx.has_finalize:
                extras = ctx.algo.client_finalize(
                    ctx.model, params, jnp.asarray(cdata.x),
                    jnp.asarray(cdata.y), jnp.ones((cdata.n,), jnp.float32),
                    payload)
            new_states.append(
                ctx.algo.update_client_state(state, params, payload)
                if ctx.has_state_update else state)
            uploads.append({"params": params, **extras})
            weights.append(float(mat.n))
            losses.append(float(np.mean(step_losses)) if step_losses else 0.0)
        return RoundResult(uploads, weights, losses, new_states)


class VmapExecutor:
    """One jitted call per round: vmap the per-client scan over a stacked
    client axis.  Wall-clock stops scaling linearly with participation."""

    name = "vmap"

    # -- cached jitted stages (cache lives on ctx, see RoundContext) -----
    def _round_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("round")
        if fn is None:
            fn = jax.jit(jax.vmap(ctx.local_update,
                                  in_axes=(None, None, 0, 0, 0, 0, 0, None)))
            ctx.jit_cache["round"] = fn
        return fn

    def _finalize_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("finalize")
        if fn is None:
            def one(params, x, y, mask, payload):
                return ctx.algo.client_finalize(ctx.model, params, x, y,
                                                mask, payload)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 0, None)))
            ctx.jit_cache["finalize"] = fn
        return fn

    def _state_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("state")
        if fn is None:
            def one(state, params, payload):
                return ctx.algo.update_client_state(state, params, payload)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, None)))
            ctx.jit_cache["state"] = fn
        return fn

    # -- the stacked computation (ShardMapExecutor overrides this) -------
    def _execute(self, ctx, global_params, payload, states_stacked,
                 xs, ys, ex_mask, step_mask):
        return self._round_fn(ctx)(global_params, payload, states_stacked,
                                   xs, ys, ex_mask, step_mask, ctx.lr)

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng) -> RoundResult:
        k = len(client_data)
        mats = [materialize_client(rng, d, ctx.batch_size, ctx.epochs,
                                   ctx.max_batches) for d in client_data]
        xs, ys, ex_mask, step_mask = _pad_and_stack(mats)
        states_stacked = tree_stack(client_states)

        params_stacked, mloss = self._execute(
            ctx, global_params, payload, states_stacked, xs, ys, ex_mask,
            step_mask)

        if ctx.has_finalize:
            fx, fy, fmask = _pad_full_data(client_data)
            extras_stacked = self._finalize_fn(ctx)(params_stacked, fx, fy,
                                                    fmask, payload)
        else:
            extras_stacked = {}
        if ctx.has_state_update:
            new_states_stacked = self._state_fn(ctx)(states_stacked,
                                                     params_stacked, payload)
        else:
            new_states_stacked = None

        per_client = _tree_unstack_jit(
            (params_stacked, extras_stacked), k)
        uploads = [{"params": p, **e} for p, e in per_client]
        new_states = (_tree_unstack_jit(new_states_stacked, k)
                      if ctx.has_state_update else list(client_states))
        return RoundResult(uploads, [float(m.n) for m in mats],
                           np.asarray(mloss).astype(float).tolist(),
                           new_states)


class ShardMapExecutor(VmapExecutor):
    """Route the stacked round through a ``("clients",)`` device mesh.

    Experimental stub for the multi-device path (repro/launch idiom): each
    shard vmaps its slice of the cohort with no cross-client collectives;
    outputs stay client-stacked.  Requires the sampled-cohort size to be a
    multiple of the device count — otherwise it silently degrades to the
    single-device vmap computation.
    """

    name = "shard_map"

    def _execute(self, ctx, global_params, payload, states_stacked,
                 xs, ys, ex_mask, step_mask):
        from jax.sharding import PartitionSpec as P

        from repro.sharding import shard_map_compat

        ndev = len(jax.devices())
        k = xs.shape[0]
        if ndev == 1 or k % ndev != 0:
            return super()._execute(ctx, global_params, payload,
                                    states_stacked, xs, ys, ex_mask,
                                    step_mask)

        key = ("smap", ndev)
        jfn = ctx.jit_cache.get(key)
        if jfn is None:
            mesh = jax.make_mesh((ndev,), ("clients",))
            inner = jax.vmap(ctx.local_update,
                             in_axes=(None, None, 0, 0, 0, 0, 0, None))
            fn = shard_map_compat(
                lambda gp, pl, st, a, b, c, d: inner(gp, pl, st, a, b, c, d,
                                                     ctx.lr),
                mesh,
                in_specs=(P(), P(), P("clients"), P("clients"), P("clients"),
                          P("clients"), P("clients")),
                out_specs=(P("clients"), P("clients")))
            jfn = jax.jit(fn)
            ctx.jit_cache[key] = jfn
        return jfn(global_params, payload, states_stacked, xs, ys,
                   ex_mask, step_mask)


# ---------------------------------------------------------------------------
# registry / resolution
# ---------------------------------------------------------------------------

_EXECUTORS = {
    "sequential": SequentialExecutor,
    "vmap": VmapExecutor,
    "shard_map": ShardMapExecutor,
}


def available() -> list[str]:
    return sorted(_EXECUTORS) + ["auto"]


def get_executor(spec: "str | ClientExecutor", algo: Algorithm,
                 n_sample: int,
                 model: Optional[ModelBundle] = None) -> ClientExecutor:
    """Resolve an executor spec.

    ``"auto"`` picks the batched vmap path when the algorithm declares
    ``supports_vmap``, more than one client is sampled per round, AND the
    model's ops lower well under stacked-weight vmap (``vmap_friendly`` —
    dense models yes, conv backbones on CPU no); otherwise the sequential
    reference.  Instances pass through unchanged.
    """
    if not isinstance(spec, str):
        return spec
    if spec == "auto":
        batched_ok = (getattr(algo, "supports_vmap", False) and n_sample > 1
                      and (model is None or model.vmap_friendly))
        spec = "vmap" if batched_ok else "sequential"
    try:
        return _EXECUTORS[spec]()
    except KeyError:
        raise ValueError(
            f"unknown executor {spec!r}; available: {available()}") from None
