"""Pluggable client execution: how one round's sampled clients are trained.

The FL loop (``repro.core.fl_loop``) is algorithm-agnostic; this module makes
it *execution*-agnostic too.  A ``ClientExecutor`` consumes the round inputs
(global params, broadcast payload, per-client states and data shards) and
produces the round outputs (uploads, weights, local losses, new states) —
how the clients actually run is its business:

    SequentialExecutor   one jitted lax.scan per client, Python loop over
                         clients — the reference semantics
    VmapExecutor         pad/stack the sampled clients' batches and vmap the
                         SAME scan so one jitted XLA call trains every
                         client in parallel
    ShardMapExecutor     the multi-device path: the cohort is sharded over a
                         ``("clients",)`` device mesh with shard_map, client
                         shards live device-resident across rounds, and
                         cohorts that do not divide the device count are
                         padded with fully masked phantom clients (never a
                         silent fallback; ``strict=True`` raises if the mesh
                         route cannot run at all, i.e. on a single device)
    AsyncExecutor        buffered-asynchronous rounds over a simulated
                         heterogeneous system (``repro.core.systemsim``):
                         staleness-aware aggregation driven by the fl_loop
                         async path, ready-cohort training delegated to one
                         of the executors above (see the class docstring)

All three consume identical materialized batches (one shared host-RNG draw,
same order as the historical per-client iterator), so sequential and vmap
outputs agree to float-associativity (~1e-6 on the paper's small models).

Masking rules for ragged clients (see ``repro.core.client``):
  * every batch within a client has a uniform size ``min(B, n_k)``; across
    clients batches are zero-padded to the cohort max with a per-example
    mask that zero-weights pads inside the loss — exact, not approximate;
  * clients with fewer steps than the cohort max get whole padded steps
    masked out as identities on (params, opt_state).

The ``precompute_aux`` stage
----------------------------
KD-family algorithms distill from teachers that are FROZEN for the whole
round (FedGKD Eq. 4-5, FedDistill's label table), so their per-example
teacher tensors are round constants.  Executors therefore invoke
``Algorithm.precompute_aux(model, payload, x, y, mask)`` ONCE per round on
each client's full shard — a single jitted, inference-only batched forward,
``(K, N_max, ...) -> (K, N_max, C)`` on the stacked path — and gather the
per-batch rows through the ``MaterializedClient.picks`` indices before the
training scan.  The gathered pytree reaches ``loss_fn`` as ``aux``; the
teacher's parameters never enter the differentiated (vmapped) closure.

Contract for ``precompute_aux`` implementations:
  * PURE pytree-in/pytree-out and vmappable over a stacked client axis —
    no Python-side branching on data values;
  * FIXED output pytree structure for a given algorithm: the choice of
    "aux vs no aux" is made per-RoundContext, never per-client or
    per-round, so compiled executables are reused across rounds;
  * inference-only: executors call it outside autodiff and treat the
    result as a constant of the round (accumulate in fp32 — the result
    feeds a loss whose gradients must match the inline recomputation);
  * ``mask`` is the per-example validity vector of the padded shard;
    rows with ``mask == 0`` may contain arbitrary values — consumers see
    them only through batch gathers that the example mask zero-weights;
  * returning ``None`` (the base-class default) disables the stage.

Cross-round caching: when the aux decomposes into independently versioned
parts (``Algorithm.precompute_parts`` — FedGKD-VOTE's M buffered teachers,
of which a round replaces exactly one), the batched executors cache each
part's per-example output under ``(client_id, version_key)`` in
``RoundContext.aux_cache`` and recompute only parts with unseen keys, so
steady-state teacher inference is ~1 shard forward per round instead of M.
Requires the caller to pass stable ``client_ids`` to ``run_round``; cached
values must be bit-reproducible from (part payload, shard) alone.

The client-batched conv route
-----------------------------
On the paper's CV backbones, vmapping ``local_update`` over clients turns
every convolution into a batched-WEIGHT convolution that XLA lowers poorly
(the long-standing ROADMAP item).  Models that declare
``ModelBundle.client_batched`` consume client-STACKED params natively —
``models/resnet.py`` detects 5-D conv weights and routes through the fused
``kernels.grouped_conv.client_batched_conv`` (one feature-grouped conv with
a custom VJP) — so for algorithms that provide ``Algorithm.batched_loss_fn``
the batched executors swap the vmapped round body for
``client_lib.make_batched_local_update``: the global params broadcast to a
``(K, ...)`` stack, one fused ``value_and_grad`` of the summed per-client
losses trains the whole cohort (client params are disjoint, so the sum's
gradient IS the per-client gradients), and short rounds run as an unrolled
step loop (``lax.scan`` over resnet-sized bodies is ~19x slower on CPU).
``RoundContext(client_batched=False)`` forces the historical vmapped body —
the ``benchmarks/executor_bench.py --conv`` naive baseline — and
``ctx.telemetry["round_body"]`` records which body ran.  The ShardMap
executor reuses the same body per mesh shard (each shard trains its g
resident clients as one stacked program).

The multi-device path (ShardMapExecutor)
----------------------------------------
``ShardMapExecutor`` maps the cohort onto a 1-D ``("clients",)`` mesh over
every visible device (``repro.launch.mesh.make_clients_mesh``):

  * cohorts whose size K does not divide the device count are padded to
    ``K_pad = ceil(K / n_dev) * n_dev`` with PHANTOM clients whose step and
    example masks are all zero — the same masking machinery that makes
    ragged clients exact makes the phantoms exact identities, and their
    outputs are sliced off before aggregation and metrics;
  * each sampled client's FULL shard is materialized once into a
    device-resident slab pinned to the client's mesh slot
    (``repro.data.pipeline.ClientSlabStore``, keyed by client id) and
    re-used across rounds — per-round host→device traffic drops to the
    cohort's batch-pick indices and masks, with training batches gathered
    from the resident slab ON the owning device inside the sharded round;
  * the ``precompute_aux`` teacher forward and the ``precompute_parts`` /
    ``ModelBuffer`` part-cache run through the same mesh, so teacher logits
    are computed — and their per-version slabs cached — on the device that
    owns the client;
  * which route actually ran is logged and exposed via
    ``RoundContext.telemetry`` (``route``/``n_devices``/``padded_to``/
    ``placement`` counters); ``ShardMapExecutor(strict=True)`` raises
    instead of ever degrading to the single-device vmap computation.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import client as client_lib
from repro.core.algorithms import Algorithm
from repro.core.modelzoo import ModelBundle
from repro.data.pipeline import ClientData, ClientSlabStore, slab_rows
from repro.optim import Optimizer

_LOG = logging.getLogger("repro.executor")


# ---------------------------------------------------------------------------
# round inputs/outputs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RoundContext:
    """Everything fixed across rounds that an executor needs."""
    algo: Algorithm
    model: ModelBundle
    opt: Optimizer
    lr: float
    batch_size: int
    epochs: int
    max_batches: Optional[int] = None
    precompute: bool = True   # False forces the inline (no-aux) loss path
    # cap on device-resident client shards (ShardMapExecutor; LRU-evicted
    # past the cap).  None = unbounded — right for full participation, but
    # long partial-participation runs on real accelerators should bound it
    placement_max_resident: Optional[int] = None
    # the CLIENT-BATCHED round body (see "The client-batched conv route" in
    # the module docstring): "auto" uses it whenever the model declares
    # ``client_batched`` AND the algorithm provides ``batched_loss_fn``;
    # False forces the historical vmapped body (the benchmarks' naive
    # baseline); True additionally raises if the pair cannot support it
    client_batched: "bool | str" = "auto"
    # -- fixed-slot wave geometry (the async pipelined path) -------------
    # When set, every batched run_round pads its cohort to ``wave_slots``
    # phantom-masked client slots and its batch stacks to
    # ``pad_steps``/``pad_batch`` steps×examples (full shards to
    # ``pad_rows`` rows), so CHURNING wave sizes hit ONE compiled round
    # body instead of retracing per distinct (K, S, B) — see
    # ``AsyncExecutor``.  None (the default) keeps the historical
    # per-wave-maxima shapes.  Padding is exact, not approximate: phantom
    # slots/steps are identities through the masking machinery and are
    # sliced off before anything downstream sees them.
    wave_slots: Optional[int] = None
    pad_steps: Optional[int] = None
    pad_batch: Optional[int] = None
    pad_rows: Optional[int] = None
    # deferred-loss mode: run_round returns local losses as on-device
    # scalars instead of forcing a host sync per wave — the async
    # pipelined loop converts them at aggregation, which is the only
    # point allowed to block (``jax.block_until_ready`` semantics)
    deferred: bool = False

    def __post_init__(self):
        loss_fn = self.algo.loss_fn(self.model)
        # scan-based whole-client pass (vmap/shard_map paths)
        self.local_update = client_lib.make_local_update(loss_fn, self.opt)
        # client-batched whole-cohort pass: the model consumes stacked
        # params natively (conv -> kernels.grouped_conv), so the batched
        # executors can skip vmapping the round body entirely
        self.batched_local_update = None
        if self.client_batched in ("auto", True):
            bloss = (self.algo.batched_loss_fn(self.model)
                     if getattr(self.model, "client_batched", False) else None)
            if bloss is not None:
                self.batched_local_update = client_lib.make_batched_local_update(
                    bloss, self.opt)
            elif self.client_batched is True:
                raise ValueError(
                    f"client_batched=True but model "
                    f"{getattr(self.model, 'name', self.model)!r} / algorithm "
                    f"{self.algo.name!r} has no client-batched form "
                    f"(ModelBundle.client_batched + Algorithm.batched_loss_fn)")
        # per-batch step (sequential path: compiles once per batch SHAPE
        # rather than once per (steps, batch) pair like the scan would)
        self.step = client_lib.make_step(loss_fn, self.opt, jit=True)
        # jitted-artifact cache owned by THIS context (executors must not
        # key a shared cache on id(ctx): the id can be reused after gc and
        # serve another algorithm's compiled round function)
        self.jit_cache: dict = {}
        # hooks left at the Algorithm defaults are no-ops — executors skip
        # the (host + dispatch) work of calling them entirely
        cls = type(self.algo)
        self.has_finalize = cls.client_finalize is not Algorithm.client_finalize
        self.has_state_update = (
            cls.update_client_state is not Algorithm.update_client_state)
        self.has_precompute = (
            self.precompute
            and cls.precompute_aux is not Algorithm.precompute_aux)
        # cross-round cache of per-(client, part-version) precompute outputs
        # (see "The precompute_aux stage" in the module docstring)
        self.aux_cache: dict = {}
        # device-resident per-client shard slabs (ShardMapExecutor) — owned
        # by the context so placement survives across rounds with the jit
        # artifacts it feeds
        self.placement = ClientSlabStore(self.placement_max_resident)
        # per-round observability: which route ran, mesh/padding geometry,
        # placement counters, parts recomputed — written by executors, read
        # by fl_loop logging and the regression tests
        self.telemetry: dict = {}
        # distinct round-body input shape signatures seen so far: each new
        # signature is exactly one XLA retrace of the round function, so
        # ``telemetry["compile_count"] == len(round_shapes)`` counts
        # compiled round bodies (the fixed-slot acceptance criterion)
        self.round_shapes: set = set()

    def note_round_shape(self, sig: tuple) -> None:
        self.round_shapes.add(sig)
        self.telemetry["compile_count"] = len(self.round_shapes)


@dataclasses.dataclass
class RoundResult:
    """Stacked-back-to-lists round outputs; shapes match the historical
    sequential loop so server_update / privacy / History are untouched."""
    uploads: list[dict]
    weights: list[float]
    local_losses: list[float]
    client_states: list[Any]


@runtime_checkable
class ClientExecutor(Protocol):
    name: str

    def run_round(self, ctx: RoundContext, global_params: Any, payload: Any,
                  client_states: list[Any], client_data: list[ClientData],
                  rng: np.random.Generator,
                  client_ids: Optional[list[int]] = None,
                  picks: Optional[list[np.ndarray]] = None) -> RoundResult:
        """``client_ids`` (stable per-client identifiers, aligned with
        ``client_data``) unlock the cross-round teacher-logit cache for
        algorithms that expose ``precompute_parts``; ``None`` disables
        caching but changes nothing else.  ``picks`` supplies pre-drawn
        batch indices (one ``materialize_picks`` array per client, same
        order as ``client_data``) so a caller that must keep ``rng`` in
        lockstep across processes (multi-host placement) can draw for the
        FULL cohort itself; ``None`` keeps the historical in-executor
        draws."""
        ...


# ---------------------------------------------------------------------------
# batch materialization (shared by all executors)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MaterializedClient:
    xs: np.ndarray      # (S_k, bs_k, ...)
    ys: np.ndarray      # (S_k, bs_k)
    n: int              # true example count (aggregation weight)
    picks: np.ndarray   # (S_k, bs_k) int32 — shard-row index of each example


def materialize_picks(rng: np.random.Generator, data: ClientData,
                      batch_size: int, epochs: int,
                      max_batches: Optional[int] = None) -> np.ndarray:
    """Draw the client's epoch batch INDICES up front: (S_k, bs_k) int32.

    Consumes ``rng`` exactly like the historical lazy ``batch_iterator``
    (one permutation per *started* epoch, partial batches wrap-padded), so
    a given seed yields the same batch sequence under every executor —
    including the shard_map path, which ships only these indices to the
    device and gathers the rows from the resident shard slab there.
    """
    n = data.n
    bs = min(batch_size, n)
    picks: list[np.ndarray] = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, bs):
            idx = order[i:i + bs]
            if len(idx) < bs:               # wrap the final partial batch
                idx = np.concatenate([idx, order[: bs - len(idx)]])
            picks.append(idx)
            if max_batches is not None and len(picks) >= max_batches:
                break
        if max_batches is not None and len(picks) >= max_batches:
            break
    return np.stack(picks).astype(np.int32)  # (S_k, bs_k)


def materialize_client(rng: np.random.Generator, data: ClientData,
                       batch_size: int, epochs: int,
                       max_batches: Optional[int] = None) -> MaterializedClient:
    """``materialize_picks`` plus the host-side row gather (the sequential
    and vmap executors feed the gathered batches straight to the device)."""
    sel = materialize_picks(rng, data, batch_size, epochs, max_batches)
    return MaterializedClient(data.x[sel], data.y[sel], data.n, sel)


def client_from_picks(data: ClientData, sel: np.ndarray) -> MaterializedClient:
    """``materialize_client`` with the indices already drawn — the
    multi-host round pre-draws picks for the whole cohort (rng lockstep)
    and hands each executor only its owned slice."""
    sel = np.asarray(sel, np.int32)
    return MaterializedClient(data.x[sel], data.y[sel], data.n, sel)


def _pad_and_stack(mats: list[MaterializedClient], k_pad: Optional[int] = None,
                   s_pad: Optional[int] = None, b_pad: Optional[int] = None):
    """(K, S, B, ...) arrays + example mask (K, S, B) + pick indices
    (K, S, B) + step mask (K, S).  Padded picks point at row 0 — harmless,
    the example mask zero-weights whatever they gather.

    ``k_pad``/``s_pad``/``b_pad`` raise the stack dimensions to fixed
    targets (never below the cohort maxima): rows beyond ``len(mats)`` are
    phantom clients with all-zero masks, extra steps/examples are masked
    pads like any ragged client's — the fixed-slot wave geometry."""
    S = max(max(m.xs.shape[0] for m in mats), s_pad or 0)
    B = max(max(m.xs.shape[1] for m in mats), b_pad or 0)
    k = max(len(mats), k_pad or 0)
    feat = mats[0].xs.shape[2:]
    xs = np.zeros((k, S, B) + feat, mats[0].xs.dtype)
    ys = np.zeros((k, S, B), mats[0].ys.dtype)
    ex_mask = np.zeros((k, S, B), np.float32)
    picks = np.zeros((k, S, B), np.int32)
    step_mask = np.zeros((k, S), bool)
    for i, m in enumerate(mats):
        s, b = m.xs.shape[:2]
        xs[i, :s, :b] = m.xs
        ys[i, :s, :b] = m.ys
        ex_mask[i, :s, :b] = 1.0
        picks[i, :s, :b] = m.picks
        step_mask[i, :s] = True
    return (jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ex_mask),
            jnp.asarray(picks), jnp.asarray(step_mask))


def _pad_and_stack_picks(picks: list[np.ndarray], k_pad: int,
                         s_pad: Optional[int] = None,
                         b_pad: Optional[int] = None):
    """Stack per-client pick indices to (k_pad, S, B) + example mask
    (k_pad, S, B) + step mask (k_pad, S) — the shard_map path's entire
    per-round host→device payload.  Rows beyond ``len(picks)`` are phantom
    clients: all-zero masks make their every step an identity.
    ``s_pad``/``b_pad`` raise S/B to fixed targets (fixed-slot waves)."""
    S = max(max(p.shape[0] for p in picks), s_pad or 0)
    B = max(max(p.shape[1] for p in picks), b_pad or 0)
    out = np.zeros((k_pad, S, B), np.int32)
    ex_mask = np.zeros((k_pad, S, B), np.float32)
    step_mask = np.zeros((k_pad, S), bool)
    for i, p in enumerate(picks):
        s, b = p.shape
        out[i, :s, :b] = p
        ex_mask[i, :s, :b] = 1.0
        step_mask[i, :s] = True
    return out, ex_mask, step_mask


def _pad_clients_axis(tree: Any, k_pad: int) -> Any:
    """Zero-pad every leaf's leading (clients) axis to ``k_pad`` (phantom
    clients' states; their updates are masked out and sliced off)."""
    def pad(leaf):
        k = leaf.shape[0]
        if k == k_pad:
            return leaf
        return jnp.concatenate(
            [leaf, jnp.zeros((k_pad - k,) + leaf.shape[1:], leaf.dtype)])
    return jax.tree_util.tree_map(pad, tree)


def _pad_full_data(client_data: list[ClientData], cache: Optional[dict] = None,
                   cohort_key=None, k_pad: Optional[int] = None,
                   n_pad: Optional[int] = None):
    """Stack each client's FULL shard to (K, N_max, ...) + mask for the
    vmapped ``client_finalize`` / ``precompute_aux`` hooks.

    Shards are immutable across rounds, so with ``cache``/``cohort_key``
    (the sampled client-id tuple) a repeated cohort skips the host padding
    work entirely.  The cache holds ONE entry: only a cohort repeated
    back-to-back (fixed-cohort loops, benchmarks) ever hits — under random
    partial participation every round keys differently, and retaining
    misses would pin (K, N_max, ...) device stacks for nothing.

    ``k_pad``/``n_pad`` raise the client/row dimensions to fixed targets
    (fixed-slot waves); phantom rows carry zero values behind a zero mask.
    """
    if cache is not None and cohort_key is not None:
        hit = cache.get(cohort_key)
        if hit is not None:
            return hit
    n_max = max(max(d.n for d in client_data), n_pad or 0)
    k = max(len(client_data), k_pad or 0)
    feat = client_data[0].x.shape[1:]
    xs = np.zeros((k, n_max) + feat, client_data[0].x.dtype)
    ys = np.zeros((k, n_max), client_data[0].y.dtype)
    mask = np.zeros((k, n_max), np.float32)
    for i, d in enumerate(client_data):
        xs[i, :d.n] = d.x
        ys[i, :d.n] = d.y
        mask[i, :d.n] = 1.0
    out = jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)
    if cache is not None and cohort_key is not None:
        cache.clear()                   # single-entry: no device-array pile
        cache[cohort_key] = out
    return out


def tree_stack(trees: list[Any]) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree: Any, k: int) -> list[Any]:
    return [jax.tree_util.tree_map(lambda l: l[i], tree) for i in range(k)]


@functools.partial(jax.jit, static_argnums=1)
def _tree_unstack_jit(tree: Any, k: int) -> list[Any]:
    """tree_unstack as ONE dispatch (eager per-leaf slicing costs ~K·L tiny
    device ops per round, which dominates small-model rounds)."""
    return tree_unstack(tree, k)


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class SequentialExecutor:
    """Reference implementation: clients one at a time, one jitted step per
    batch (the historical loop — no padding, no masks)."""

    name = "sequential"

    def _precompute_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("precompute_seq")
        if fn is None:
            def stage(payload, x, y, mask, picks):
                aux = ctx.algo.precompute_aux(ctx.model, payload, x, y, mask)
                # gather every step's batch rows in one dispatch: (S, B, ...)
                return jax.tree_util.tree_map(lambda l: l[picks], aux)

            fn = jax.jit(stage)
            ctx.jit_cache["precompute_seq"] = fn
        return fn

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng, client_ids=None,
                  picks=None) -> RoundResult:
        ctx.telemetry["route"] = "sequential"
        uploads, weights, losses, new_states = [], [], [], []
        for ci, (state, cdata) in enumerate(zip(client_states, client_data)):
            mat = (client_from_picks(cdata, picks[ci])
                   if picks is not None else
                   materialize_client(rng, cdata, ctx.batch_size, ctx.epochs,
                                      ctx.max_batches))
            if ctx.has_precompute:
                # one jitted (precompute + all-steps gather) dispatch, then
                # cheap per-step numpy views — never per-step device slicing
                gathered = self._precompute_fn(ctx)(
                    payload, jnp.asarray(cdata.x), jnp.asarray(cdata.y),
                    jnp.ones((cdata.n,), jnp.float32), jnp.asarray(mat.picks))
                aux_steps = jax.tree_util.tree_map(np.asarray, gathered)
            params, opt_state = global_params, ctx.opt.init(global_params)
            step_losses = []
            for s in range(mat.xs.shape[0]):
                aux_b = (jax.tree_util.tree_map(lambda l: l[s], aux_steps)
                         if ctx.has_precompute else ())
                params, opt_state, loss, _ = ctx.step(
                    params, opt_state, payload, state,
                    jnp.asarray(mat.xs[s]), jnp.asarray(mat.ys[s]), None,
                    aux_b, ctx.lr)
                step_losses.append(float(loss))
            extras = {}
            if ctx.has_finalize:
                extras = ctx.algo.client_finalize(
                    ctx.model, params, jnp.asarray(cdata.x),
                    jnp.asarray(cdata.y), jnp.ones((cdata.n,), jnp.float32),
                    payload)
            new_states.append(
                ctx.algo.update_client_state(state, params, payload)
                if ctx.has_state_update else state)
            uploads.append({"params": params, **extras})
            weights.append(float(mat.n))
            losses.append(float(np.mean(step_losses)) if step_losses else 0.0)
        return RoundResult(uploads, weights, losses, new_states)


class VmapExecutor:
    """One jitted call per round: vmap the per-client scan over a stacked
    client axis.  Wall-clock stops scaling linearly with participation."""

    name = "vmap"

    # -- cached jitted stages (cache lives on ctx, see RoundContext) -----
    def _round_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("round")
        if fn is None:
            # the (xs, ys, ex_mask) batch stacks are rebuilt fresh every
            # round, so their buffers can be donated back to XLA — a real
            # win on accelerators, a warning no-op on the CPU backend
            donate = (() if jax.default_backend() == "cpu" else (3, 4, 5))
            if ctx.batched_local_update is not None:
                # client-batched body: one fused cohort program (stacked
                # params through the model, grouped-conv kernels) instead
                # of vmapping the per-client scan — same signature
                fn = jax.jit(ctx.batched_local_update,
                             donate_argnums=donate)
            else:
                fn = jax.jit(jax.vmap(ctx.local_update,
                                      in_axes=(None, None, 0, 0, 0, 0, 0, 0,
                                               None)),
                             donate_argnums=donate)
            ctx.jit_cache["round"] = fn
        return fn

    def _precompute_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("precompute")
        if fn is None:
            def stage(payload, fx, fy, fmask):
                # one inference-only batched forward over every client's
                # full shard: (K, N_max, ...) -> per-example aux leaves
                return jax.vmap(
                    lambda x, y, m: ctx.algo.precompute_aux(
                        ctx.model, payload, x, y, m))(fx, fy, fmask)

            fn = jax.jit(stage)
            ctx.jit_cache["precompute"] = fn
        return fn

    def _gather_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("gather")
        if fn is None:
            # per-batch rows: leaves (K, N_max, ...) -> (K, S, B, ...)
            fn = jax.jit(jax.vmap(lambda a, p: jax.tree_util.tree_map(
                lambda l: l[p], a)))
            ctx.jit_cache["gather"] = fn
        return fn

    def _finalize_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("finalize")
        if fn is None:
            def one(params, x, y, mask, payload):
                return ctx.algo.client_finalize(ctx.model, params, x, y,
                                                mask, payload)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 0, None)))
            ctx.jit_cache["finalize"] = fn
        return fn

    def _state_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("state")
        if fn is None:
            def one(state, params, payload):
                return ctx.algo.update_client_state(state, params, payload)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, None)))
            ctx.jit_cache["state"] = fn
        return fn

    # -- the stacked computation (ShardMapExecutor overrides this) -------
    def _execute(self, ctx, global_params, payload, states_stacked,
                 xs, ys, ex_mask, aux, step_mask):
        return self._round_fn(ctx)(global_params, payload, states_stacked,
                                   xs, ys, ex_mask, aux, step_mask, ctx.lr)

    def _incremental_aux(self, ctx: RoundContext, payload, parts_spec,
                         client_ids, client_data, full):
        """Cross-round cached precompute: recompute only the parts whose
        version key is new for a sampled client (steady state: ONE teacher
        forward over the stacked cohort per round instead of M), then
        combine.  Missing parts are computed on the stacked (K, N_max)
        shard — one dispatch per missing version, never per client."""
        keys, get_part = parts_spec
        cohort = tuple(client_ids)
        part_fn = ctx.jit_cache.get("part")
        if part_fn is None:
            part_fn = jax.jit(jax.vmap(
                lambda pp, x: ctx.algo.precompute_part(ctx.model, pp, x),
                in_axes=(None, 0)))
            ctx.jit_cache["part"] = part_fn
        fx = full[0]
        for cid in client_ids:
            ctx.aux_cache.setdefault(cid, {})

        stacked_by_key: dict = {}       # freshly computed parts, deduped

        def ensure_stacked(m, key):
            if key not in stacked_by_key:
                stacked_by_key[key] = part_fn(get_part(m), fx)  # (K, N_max, .)
                ctx.telemetry["parts_computed"] = (
                    ctx.telemetry.get("parts_computed", 0) + 1)
            return stacked_by_key[key]

        # fill the per-client numpy cache for any (client, version) misses
        for m, key in enumerate(keys):
            if any(key not in ctx.aux_cache[cid] for cid in client_ids):
                arr = np.asarray(ensure_stacked(m, key))
                for i, (cid, d) in enumerate(zip(client_ids, client_data)):
                    if key not in ctx.aux_cache[cid]:
                        ctx.aux_cache[cid][key] = arr[i, :d.n]

        # per-VERSION device slabs (K, N_max, ...): version keys ROTATE
        # positions every round, so the cache must be keyed by version, not
        # position — a repeated cohort then re-stacks M resident slabs and
        # uploads nothing but the one freshly computed part
        dev = ctx.jit_cache.get("parts_dev")
        if dev is None or dev["cohort"] != cohort:
            dev = {"cohort": cohort, "slabs": {}}
            ctx.jit_cache["parts_dev"] = dev
        slabs = dev["slabs"]
        # slab geometry comes from the (possibly slot-padded) full stack,
        # not the raw cohort: phantom rows stay zero behind the mask
        k = int(fx.shape[0])
        n_max = int(fx.shape[1])
        tail = ctx.aux_cache[client_ids[0]][keys[0]].shape[1:]
        for m, key in enumerate(keys):
            if key in slabs:
                continue
            if key in stacked_by_key:       # freshly computed, already (K,N)
                slabs[key] = stacked_by_key[key]
            else:                           # host assembly of ONE part only
                buf = np.zeros((k, n_max) + tail, np.float32)
                for i, (cid, d) in enumerate(zip(client_ids, client_data)):
                    buf[i, :d.n] = ctx.aux_cache[cid][key]
                slabs[key] = jnp.asarray(buf)
        parts = jnp.stack([slabs[key] for key in keys])   # (P, K, N_max, ..)
        # evict versions that rotated out of the part key set
        keyset = set(keys)
        dev["slabs"] = {kk: v for kk, v in slabs.items() if kk in keyset}
        for cid in client_ids:
            ctx.aux_cache[cid] = {kk: v for kk, v in ctx.aux_cache[cid].items()
                                  if kk in keyset}
        combine_fn = ctx.jit_cache.get("combine")
        if combine_fn is None:
            combine_fn = jax.jit(jax.vmap(
                lambda pl, pr, x, y, msk: ctx.algo.precompute_combine(
                    pl, pr, x, y, msk),
                in_axes=(None, 1, 0, 0, 0)))
            ctx.jit_cache["combine"] = combine_fn
        return combine_fn(payload, jnp.asarray(parts), *full)

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng, client_ids=None,
                  picks=None) -> RoundResult:
        ctx.telemetry["route"] = "vmap"
        ctx.telemetry["round_body"] = (
            "client_batched" if ctx.batched_local_update is not None
            else "vmap")
        k = len(client_data)
        # fixed-slot waves: pad the cohort axis to ``wave_slots`` phantom
        # clients (and rows/steps/batch to the population-wide targets) so
        # every wave, whatever its size, runs the SAME compiled body
        k_pad = max(k, ctx.wave_slots) if ctx.wave_slots else k
        full = None
        aux_full = None
        if ctx.has_precompute or ctx.has_finalize:
            full = _pad_full_data(
                client_data, cache=ctx.jit_cache.setdefault("full_data", {}),
                cohort_key=(tuple(client_ids)
                            if client_ids is not None else None),
                k_pad=k_pad, n_pad=ctx.pad_rows)
        if ctx.has_precompute:
            parts_spec = (ctx.algo.precompute_parts(payload)
                          if client_ids is not None else None)
            if parts_spec is not None:
                aux_full = self._incremental_aux(ctx, payload, parts_spec,
                                                 client_ids, client_data,
                                                 full)
            else:
                # dispatch the (async) teacher forward FIRST: it needs no
                # batch picks, so the device crunches it while the host
                # materializes and pads the round's batches below
                aux_full = self._precompute_fn(ctx)(payload, *full)

        mats = ([client_from_picks(d, p)
                 for d, p in zip(client_data, picks)]
                if picks is not None else
                [materialize_client(rng, d, ctx.batch_size, ctx.epochs,
                                    ctx.max_batches) for d in client_data])
        xs, ys, ex_mask, picks, step_mask = _pad_and_stack(
            mats, k_pad=k_pad, s_pad=ctx.pad_steps, b_pad=ctx.pad_batch)
        states_real = tree_stack(client_states)
        states_stacked = _pad_clients_axis(states_real, k_pad)
        aux = (self._gather_fn(ctx)(aux_full, picks)
               if ctx.has_precompute else ())
        ctx.note_round_shape(("round", ctx.telemetry["round_body"])
                             + tuple(xs.shape))

        params_padded, mloss_padded = self._execute(
            ctx, global_params, payload, states_stacked, xs, ys, ex_mask,
            aux, step_mask)
        # drop phantom slots before anything downstream sees them
        params_stacked = (jax.tree_util.tree_map(lambda l: l[:k],
                                                 params_padded)
                          if k_pad > k else params_padded)
        mloss = mloss_padded[:k] if k_pad > k else mloss_padded

        if ctx.has_finalize:
            fx, fy, fmask = full
            extras_stacked = self._finalize_fn(ctx)(params_stacked, fx[:k],
                                                    fy[:k], fmask[:k],
                                                    payload)
        else:
            extras_stacked = {}
        if ctx.has_state_update:
            new_states_stacked = self._state_fn(ctx)(states_real,
                                                     params_stacked, payload)
        else:
            new_states_stacked = None

        per_client = _tree_unstack_jit(
            (params_stacked, extras_stacked), k)
        uploads = [{"params": p, **e} for p, e in per_client]
        new_states = (_tree_unstack_jit(new_states_stacked, k)
                      if ctx.has_state_update else list(client_states))
        return RoundResult(uploads, [float(m.n) for m in mats],
                           mloss if ctx.deferred
                           else np.asarray(mloss).astype(float).tolist(),
                           new_states)


class ShardMapExecutor(VmapExecutor):
    """The multi-device executor: cohort sharded over a ``("clients",)``
    mesh, client shards device-resident across rounds.

    See "The multi-device path" in the module docstring.  Cohorts that do
    not divide the device count are padded with fully masked phantom
    clients (no fallback); the only configuration the mesh route cannot
    serve is a single-device host, where it degrades to the vmap
    computation with a logged warning — or raises under ``strict=True``.
    """

    name = "shard_map"

    def __init__(self, strict: bool = False):
        self.strict = strict

    # -- mesh + sharded jitted stages ------------------------------------
    def _mesh(self, ctx: RoundContext, ndev: int):
        key = ("clients_mesh", ndev)
        mesh = ctx.jit_cache.get(key)
        if mesh is None:
            from repro.launch.mesh import (make_clients_mesh,
                                           make_local_clients_mesh)
            mesh = (make_local_clients_mesh(ndev)
                    if jax.process_count() > 1 else make_clients_mesh(ndev))
            ctx.jit_cache[key] = mesh
        return mesh

    def _sharded_round_fn(self, ctx: RoundContext, mesh) -> Callable:
        key = ("smap_round", mesh.devices.size)
        jfn = ctx.jit_cache.get(key)
        if jfn is None:
            from repro.sharding import shard_map_compat

            def per_shard(gp, pl, st, fx, fy, picks, ex_mask, step_mask,
                          aux_full):
                # batch rows gathered from the resident slab ON the device
                # that owns the client — the host never ships (S, B, ...)
                # batch tensors for this path
                if ctx.batched_local_update is not None:
                    # client-batched body on this shard's g resident
                    # clients: gather every client's batches, then run the
                    # fused stacked round (grouped-conv route) — no vmap
                    gather = jax.vmap(lambda f, p: f[p])
                    xs = gather(fx, picks)
                    ys = gather(fy, picks)
                    aux_rows = jax.tree_util.tree_map(
                        lambda l: jax.vmap(lambda a, p: a[p])(l, picks),
                        aux_full)
                    return ctx.batched_local_update(
                        gp, pl, st, xs, ys, ex_mask, aux_rows, step_mask,
                        ctx.lr)

                def one(st_i, fx_i, fy_i, p_i, em_i, sm_i, aux_i):
                    xs = fx_i[p_i]
                    ys = fy_i[p_i]
                    aux_rows = jax.tree_util.tree_map(lambda l: l[p_i],
                                                      aux_i)
                    return ctx.local_update(gp, pl, st_i, xs, ys, em_i,
                                            aux_rows, sm_i, ctx.lr)

                return jax.vmap(one)(st, fx, fy, picks, ex_mask, step_mask,
                                     aux_full)

            fn = shard_map_compat(
                per_shard, mesh,
                in_specs=(P(), P(), P("clients"), P("clients"), P("clients"),
                          P("clients"), P("clients"), P("clients"),
                          P("clients")),
                out_specs=(P("clients"), P("clients")))
            jfn = jax.jit(fn)
            ctx.jit_cache[key] = jfn
        return jfn

    def _sharded_precompute_fn(self, ctx: RoundContext, mesh) -> Callable:
        key = ("smap_pre", mesh.devices.size)
        jfn = ctx.jit_cache.get(key)
        if jfn is None:
            from repro.sharding import shard_map_compat

            def per_shard(pl, fx, fy, fmask):
                return jax.vmap(
                    lambda x, y, m: ctx.algo.precompute_aux(
                        ctx.model, pl, x, y, m))(fx, fy, fmask)

            fn = shard_map_compat(
                per_shard, mesh,
                in_specs=(P(), P("clients"), P("clients"), P("clients")),
                out_specs=P("clients"))
            jfn = jax.jit(fn)
            ctx.jit_cache[key] = jfn
        return jfn

    def _sharded_part_fn(self, ctx: RoundContext, mesh) -> Callable:
        key = ("smap_part", mesh.devices.size)
        jfn = ctx.jit_cache.get(key)
        if jfn is None:
            from repro.sharding import shard_map_compat

            def per_shard(pp, fx):
                return jax.vmap(
                    lambda x: ctx.algo.precompute_part(ctx.model, pp,
                                                       x))(fx)

            fn = shard_map_compat(per_shard, mesh,
                                  in_specs=(P(), P("clients")),
                                  out_specs=P("clients"))
            jfn = jax.jit(fn)
            ctx.jit_cache[key] = jfn
        return jfn

    def _sharded_combine_fn(self, ctx: RoundContext, mesh,
                            n_parts: int) -> Callable:
        key = ("smap_combine", mesh.devices.size, n_parts)
        jfn = ctx.jit_cache.get(key)
        if jfn is None:
            from repro.sharding import shard_map_compat

            def per_shard(pl, parts, fx, fy, fmask):
                stacked = jnp.stack(parts)          # (P, g, rows, ...)
                return jax.vmap(
                    lambda pr, x, y, m: ctx.algo.precompute_combine(
                        pl, pr, x, y, m),
                    in_axes=(1, 0, 0, 0))(stacked, fx, fy, fmask)

            fn = shard_map_compat(
                per_shard, mesh,
                in_specs=(P(), P("clients"), P("clients"), P("clients"),
                          P("clients")),
                out_specs=P("clients"))
            jfn = jax.jit(fn)
            ctx.jit_cache[key] = jfn
        return jfn

    # -- device-resident cohort assembly ---------------------------------
    def _resident_cohort(self, ctx: RoundContext, mesh,
                         client_data: list[ClientData],
                         client_ids: Optional[list[int]], k_pad: int,
                         rows: Optional[int] = None):
        """(k_pad, rows, ...) x/y/mask stacks sharded ``P("clients")``,
        assembled from the per-client resident slabs in ``ctx.placement``.

        Assembly is pure device work (pad + stack of resident arrays);
        the host uploads a shard only the first time a client is seen.
        A back-to-back repeated cohort skips even the device-side
        restack via a single-entry cache (mirrors ``_pad_full_data``)."""
        devices = list(mesh.devices.reshape(-1))
        ndev = len(devices)
        g = k_pad // ndev
        if rows is None:
            rows = max(slab_rows(d.n) for d in client_data)
        cohort_key = (tuple(client_ids), rows, ndev) \
            if client_ids is not None else None
        cache = ctx.jit_cache.setdefault("slab_stack", {})
        if cohort_key is not None and cache.get("key") == cohort_key:
            return cache["value"]

        entries: list[Optional[dict]] = []
        for i, d in enumerate(client_data):
            cid = client_ids[i] if client_ids is not None else None
            entries.append(ctx.placement.get(cid, d, devices[i // g]))
        feat = client_data[0].x.shape[1:]
        x_dtype = client_data[0].x.dtype
        pad_width = ((0, 0),) * len(feat)
        xs_shards, ys_shards = [], []
        for didx, device in enumerate(devices):
            members = entries[didx * g:(didx + 1) * g]
            xs, ys = [], []
            for e in members:
                short = rows - e["rows"]
                ex, ey = e["x"], e["y"]
                if short:
                    ex = jnp.pad(ex, ((0, short),) + pad_width)
                    ey = jnp.pad(ey, ((0, short),))
                xs.append(ex)
                ys.append(ey)
            for _ in range(g - len(members)):           # phantom clients
                xs.append(jnp.zeros((rows,) + feat, x_dtype))
                ys.append(jnp.zeros((rows,), jnp.int32))
            xs_shards.append(jax.device_put(jnp.stack(xs), device))
            ys_shards.append(jax.device_put(jnp.stack(ys), device))
        sharding = NamedSharding(mesh, P("clients"))
        fx = jax.make_array_from_single_device_arrays(
            (k_pad, rows) + feat, sharding, xs_shards)
        fy = jax.make_array_from_single_device_arrays(
            (k_pad, rows), sharding, ys_shards)
        mask = np.zeros((k_pad, rows), np.float32)
        for i, d in enumerate(client_data):
            mask[i, :d.n] = 1.0
        # process-local -> global assembly: single-process this is a
        # device_put; in a multi-process topology every host contributes
        # the mask rows its devices own (same shim for both)
        from repro.sharding import make_array_from_process_local_data_compat
        fmask = make_array_from_process_local_data_compat(sharding, mask)
        out = (fx, fy, fmask)
        if cohort_key is not None:
            cache.clear()
            cache["key"] = cohort_key
            cache["value"] = out
        return out

    def _stack_to_mesh(self, mesh, pieces: list, rows: int, k_pad: int,
                       dtype):
        """Assemble per-client device arrays ``(rows_i, ...)`` into one
        ``(k_pad, rows, ...)`` stack sharded ``P("clients")`` — pad/trim
        each piece to ``rows`` on its slot device, phantom slots zero.
        Device work only; nothing round-trips through the host."""
        devices = list(mesh.devices.reshape(-1))
        g = k_pad // len(devices)
        tail = pieces[0].shape[1:]
        pad_width = ((0, 0),) * len(tail)
        shards = []
        for didx, device in enumerate(devices):
            members = pieces[didx * g:(didx + 1) * g]
            arrs = []
            for p in members:
                p = jax.device_put(p, device)
                if p.shape[0] < rows:
                    p = jnp.pad(p, ((0, rows - p.shape[0]),) + pad_width)
                elif p.shape[0] > rows:
                    p = p[:rows]
                arrs.append(p)
            for _ in range(g - len(arrs)):
                arrs.append(jnp.zeros((rows,) + tail, dtype))
            shards.append(jax.device_put(jnp.stack(arrs), device))
        return jax.make_array_from_single_device_arrays(
            (k_pad, rows) + tail, NamedSharding(mesh, P("clients")), shards)

    def _incremental_aux_sharded(self, ctx: RoundContext, mesh, payload,
                                 parts_spec, client_ids, client_data, full):
        """The parts cache on the mesh.  Two layers, mirroring the vmap
        path but with everything device-resident:

          * per-(client_id, version) part outputs in ``ctx.aux_cache`` —
            device arrays trimmed to the client's own slab rows, so the
            cache survives cohort churn under partial participation;
          * per-version ``(k_pad, rows, ...)`` slabs sharded
            ``P("clients")`` in ``jit_cache["parts_smap"]``, rebuilt from
            the per-client layer when the cohort (or its slab geometry)
            changes — a reassembly, not a recompute.

        A version is recomputed (ONE sharded teacher forward over the
        whole cohort) only when some sampled client has never seen it —
        the steady state stays one forward per round however the cohort
        rotates."""
        keys, get_part = parts_spec
        fx, fy, fmask = full
        rows = int(fx.shape[1])
        k_pad = int(fx.shape[0])
        cohort = (tuple(client_ids), rows)
        for cid in client_ids:
            ctx.aux_cache.setdefault(cid, {})
        dev = ctx.jit_cache.get("parts_smap")
        if dev is None or dev["cohort"] != cohort:
            dev = {"cohort": cohort, "slabs": {}}
            ctx.jit_cache["parts_smap"] = dev
        slabs = dev["slabs"]
        part_fn = self._sharded_part_fn(ctx, mesh)
        own_rows = [slab_rows(d.n) for d in client_data]
        for m, key in enumerate(keys):
            if key in slabs:
                continue
            if any(key not in ctx.aux_cache[cid] for cid in client_ids):
                out = part_fn(get_part(m), fx)      # sharded (k_pad, R, .)
                ctx.telemetry["parts_computed"] = (
                    ctx.telemetry.get("parts_computed", 0) + 1)
                for i, cid in enumerate(client_ids):
                    if key not in ctx.aux_cache[cid]:
                        ctx.aux_cache[cid][key] = out[i, :own_rows[i]]
                slabs[key] = out
            else:                   # every client resident: reassemble only
                slabs[key] = self._stack_to_mesh(
                    mesh, [ctx.aux_cache[cid][key] for cid in client_ids],
                    rows, k_pad, jnp.float32)
        keyset = set(keys)
        dev["slabs"] = {kk: v for kk, v in slabs.items() if kk in keyset}
        for cid in client_ids:
            ctx.aux_cache[cid] = {kk: v for kk, v in
                                  ctx.aux_cache[cid].items() if kk in keyset}
        combine = self._sharded_combine_fn(ctx, mesh, len(keys))
        parts = tuple(dev["slabs"][key] for key in keys)
        return combine(payload, parts, fx, fy, fmask)

    # -- the round ---------------------------------------------------------
    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng, client_ids=None,
                  picks=None) -> RoundResult:
        # a multi-process topology shards each host's owned cohort slice
        # over its LOCAL devices; single-process the two sets are equal
        ndev = (len(jax.local_devices()) if jax.process_count() > 1
                else len(jax.devices()))
        if ndev == 1:
            if self.strict:
                raise RuntimeError(
                    "ShardMapExecutor(strict=True): only one device is "
                    "visible, the clients mesh cannot run.  On a CPU host "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    "before the first jax import, or drop strict to allow "
                    "the vmap fallback.")
            _LOG.warning(
                "shard_map executor: single visible device — degrading to "
                "the vmap computation (set XLA_FLAGS="
                "--xla_force_host_platform_device_count=N for a real mesh)")
            result = super().run_round(ctx, global_params, payload,
                                       client_states, client_data, rng,
                                       client_ids, picks)
            ctx.telemetry.update(route="vmap-fallback", n_devices=1)
            return result
        return self._run_sharded(ctx, global_params, payload, client_states,
                                 client_data, rng, client_ids, ndev,
                                 picks=picks)

    def _run_sharded(self, ctx, global_params, payload, client_states,
                     client_data, rng, client_ids, ndev,
                     picks=None) -> RoundResult:
        mesh = self._mesh(ctx, ndev)
        k = len(client_data)
        # fixed-slot waves: pad cohorts up to ``wave_slots`` BEFORE the
        # mesh rounding so every wave lands on the same (k_pad, rows, S, B)
        # geometry and the sharded round body never retraces
        k_eff = max(k, ctx.wave_slots) if ctx.wave_slots else k
        g = -(-k_eff // ndev)
        k_pad = g * ndev
        rows = max(slab_rows(d.n) for d in client_data)
        if ctx.pad_rows is not None:
            rows = max(rows, slab_rows(ctx.pad_rows))
        full = self._resident_cohort(ctx, mesh, client_data, client_ids,
                                     k_pad, rows=rows)
        aux_full: Any = ()
        if ctx.has_precompute:
            parts_spec = (ctx.algo.precompute_parts(payload)
                          if client_ids is not None else None)
            if parts_spec is not None:
                aux_full = self._incremental_aux_sharded(
                    ctx, mesh, payload, parts_spec, client_ids, client_data,
                    full)
            else:
                aux_full = self._sharded_precompute_fn(ctx, mesh)(payload,
                                                                  *full)

        picks_list = (list(picks) if picks is not None else
                      [materialize_picks(rng, d, ctx.batch_size, ctx.epochs,
                                         ctx.max_batches)
                       for d in client_data])
        picks, ex_mask, step_mask = _pad_and_stack_picks(
            picks_list, k_pad, s_pad=ctx.pad_steps, b_pad=ctx.pad_batch)
        sharding = NamedSharding(mesh, P("clients"))
        picks = jax.device_put(picks, sharding)
        ex_mask = jax.device_put(ex_mask, sharding)
        step_mask = jax.device_put(step_mask, sharding)
        states_stacked = tree_stack(client_states)
        states_padded = _pad_clients_axis(states_stacked, k_pad)

        fx, fy, fmask = full
        ctx.note_round_shape(("smap_round", ndev, rows)
                             + tuple(picks.shape))
        params_padded, mloss_padded = self._sharded_round_fn(ctx, mesh)(
            global_params, payload, states_padded, fx, fy, picks, ex_mask,
            step_mask, aux_full)
        # drop the phantom clients before anything downstream sees them
        params_stacked = jax.tree_util.tree_map(lambda l: l[:k],
                                                params_padded)
        mloss = mloss_padded[:k]

        if ctx.has_finalize:
            extras_stacked = self._finalize_fn(ctx)(
                params_stacked, fx[:k], fy[:k], fmask[:k], payload)
        else:
            extras_stacked = {}
        if ctx.has_state_update:
            new_states_stacked = self._state_fn(ctx)(states_stacked,
                                                     params_stacked, payload)
        else:
            new_states_stacked = None

        per_client = _tree_unstack_jit((params_stacked, extras_stacked), k)
        uploads = [{"params": p, **e} for p, e in per_client]
        new_states = (_tree_unstack_jit(new_states_stacked, k)
                      if ctx.has_state_update else list(client_states))
        ctx.telemetry.update(route="shard_map", n_devices=ndev, cohort=k,
                             padded_to=k_pad,
                             round_body=("client_batched"
                                         if ctx.batched_local_update
                                         is not None else "vmap"),
                             placement=ctx.placement.stats())
        _LOG.debug("shard_map round: K=%d padded to %d on %d devices", k,
                   k_pad, ndev)
        return RoundResult(uploads, [float(d.n) for d in client_data],
                           mloss if ctx.deferred
                           else np.asarray(mloss).astype(float).tolist(),
                           new_states)


class AsyncExecutor:
    """Straggler-aware buffered-asynchronous rounds.

    This executor changes the ROUND STRUCTURE, not just how a cohort
    trains: clients run on a simulated heterogeneous system
    (``repro.core.systemsim``), dispatch local updates tagged with the
    global version they started from, and the server aggregates a buffer
    of ``buffer_size`` completions with pluggable staleness weighting
    (``repro.core.server.async_aggregation_weights``).  The sampled
    in-flight concurrency stays at the task's cohort size; every
    aggregation consumes the B earliest completions and refills the fleet
    with B freshly sampled idle clients.

    Because the structure differs, the drive loop lives in
    ``repro.core.fl_loop`` (version counters, async history records); this
    class is the configuration + the READY-COHORT trainer: each dispatch
    wave — the clients starting from the same global version — is trained
    through an ordinary inner executor (``vmap``/``shard_map``/
    ``sequential``), so the jitted round bodies, the teacher-precompute
    pipeline and the device-resident slab placement are all reused
    unchanged.  In the degenerate regime (homogeneous speeds, full buffer
    B == cohort, zero staleness) the async loop reproduces the synchronous
    executors' numbers to < 1e-5 — the equivalence suite pins that down.

    Knobs:
      buffer_size       aggregation buffer B (default: the cohort size —
                        the synchronous-equivalent "full buffer")
      staleness         "constant" | "polynomial" | "fedgkd" (the KD
                        teacher buffer absorbs stale models, see
                        ``Algorithm.absorb_stale``)
      staleness_a       polynomial decay exponent (1+s)^(-a)
      staleness_cutoff  fedgkd scheme: staleness beyond this is dropped
                        from averaging (absorbed only); None = never drop
      profile           ``systemsim.SpeedProfile`` for per-client speeds
      availability      optional ``systemsim.Availability`` duty cycle
      inner             ready-cohort executor spec or instance
      base_step_time    virtual seconds per unit of local work — calibrate
                        with ``systemsim.measure_step_time`` to make
                        ``sim_time`` a wall-clock prediction
      pipelined         True (default) overlaps wave N+1's dispatch — the
                        host-side slab gather / batch materialization and
                        the teacher ``precompute_aux`` — with wave N's
                        on-device training: the inner executor defers its
                        loss sync (``RoundContext.deferred``) and the
                        drive loop refills the fleet BEFORE the eval
                        forces, so ``jax.block_until_ready`` happens only
                        at aggregation.  False restores the historical
                        single-stream order (the throughput benchmark's
                        baseline); values are identical either way.
      wave_slots        "auto" (default) pads every dispatch wave to a
                        fixed slot count — the buffer size — on the
                        batched inners, pinning ONE compiled round body
                        across wave-size churn (``telemetry
                        ["compile_count"]`` proves it); an int forces the
                        slot count, None/"variable" keeps the historical
                        per-wave shapes (which retrace per distinct
                        geometry).  The sequential inner has no stacked
                        shapes to pin and always runs variable.

    Fault tolerance composes from the OUTSIDE, not here: pass
    ``run_federated(faults=systemsim.FaultProfile(...))`` and the async
    drive loop draws per-dispatch crash/timeout/corrupt faults from the
    dedicated fault stream, validates completions at buffer-fill time
    (``server.validate_update``), and re-dispatches failed clients with
    capped backoff on the simulated clock — the same knobs drive the
    synchronous executors, so faults fire identically across routes.
    """

    name = "async"

    def __init__(self, buffer_size: Optional[int] = None,
                 staleness: str = "polynomial", staleness_a: float = 0.5,
                 staleness_cutoff: Optional[float] = None,
                 profile=None, availability=None,
                 inner: "str | ClientExecutor" = "auto",
                 base_step_time: float = 1.0,
                 pipelined: bool = True,
                 wave_slots: "int | str | None" = "auto"):
        from repro.core.server import STALENESS_SCHEMES
        if staleness not in STALENESS_SCHEMES:
            raise ValueError(f"unknown staleness scheme {staleness!r}; "
                             f"available: {STALENESS_SCHEMES}")
        if isinstance(inner, str) and inner == "async":
            raise ValueError("AsyncExecutor cannot nest itself as inner")
        if isinstance(wave_slots, str) and wave_slots not in ("auto",
                                                              "variable"):
            raise ValueError(f"wave_slots must be 'auto', 'variable', an "
                             f"int or None, got {wave_slots!r}")
        if isinstance(wave_slots, int) and wave_slots < 1:
            raise ValueError(f"wave_slots must be >= 1, got {wave_slots}")
        self.buffer_size = buffer_size
        self.staleness = staleness
        self.staleness_a = staleness_a
        self.staleness_cutoff = staleness_cutoff
        self.profile = profile
        self.availability = availability
        self.inner = inner
        self.base_step_time = base_step_time
        self.pipelined = pipelined
        self.wave_slots = wave_slots

    def resolve_inner(self, algo: Algorithm, n_sample: int,
                      model: Optional[ModelBundle] = None) -> ClientExecutor:
        resolved = get_executor(self.inner, algo, n_sample, model)
        if isinstance(resolved, AsyncExecutor):
            raise ValueError("AsyncExecutor cannot nest itself as inner")
        return resolved

    def resolve_wave_slots(self, buffer_size: int,
                           inner: ClientExecutor) -> Optional[int]:
        """The fixed wave slot count for this run, or None for variable
        waves.  "auto" resolves to the aggregation buffer size: refills
        dispatch exactly B clients, redispatches pad 1 → B, and the
        initial ``n_sample`` wave chunks into ceil(n_sample / B) calls of
        the SAME B-slot body (see ``fl_loop._run_async``) — so one shape
        covers every wave.  The sequential inner trains clients one at a
        time (no stacked shapes) and always runs variable."""
        if self.wave_slots in (None, "variable"):
            return None
        if getattr(inner, "name", None) == "sequential":
            return None
        return buffer_size if self.wave_slots == "auto" else self.wave_slots

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng, client_ids=None) -> RoundResult:
        raise NotImplementedError(
            "AsyncExecutor rounds are event-driven, not cohort-at-a-time; "
            "drive it through run_federated(..., executor=\"async\") (the "
            "buffered-aggregation loop lives in repro.core.fl_loop)")


# ---------------------------------------------------------------------------
# registry / resolution
# ---------------------------------------------------------------------------

_EXECUTORS = {
    "sequential": SequentialExecutor,
    "vmap": VmapExecutor,
    "shard_map": ShardMapExecutor,
    "async": AsyncExecutor,
}


def available() -> list[str]:
    return sorted(_EXECUTORS) + ["auto"]


def get_executor(spec: "str | ClientExecutor", algo: Algorithm,
                 n_sample: int,
                 model: Optional[ModelBundle] = None) -> ClientExecutor:
    """Resolve an executor spec.

    ``"auto"`` picks the batched vmap path when the algorithm declares
    ``supports_vmap``, more than one client is sampled per round, AND the
    model batches well — either its ops lower well under stacked-weight
    vmap (``vmap_friendly``: dense models) or it has the client-batched
    route (``client_batched`` models whose algorithm provides
    ``batched_loss_fn``, e.g. the resnet backbones through
    ``kernels.grouped_conv``); otherwise the sequential reference.
    Instances pass through unchanged.
    """
    if not isinstance(spec, str):
        return spec
    if spec == "auto":
        model_ok = (model is None or model.vmap_friendly
                    or (getattr(model, "client_batched", False)
                        and algo.batched_loss_fn(model) is not None))
        batched_ok = (getattr(algo, "supports_vmap", False) and n_sample > 1
                      and model_ok)
        spec = "vmap" if batched_ok else "sequential"
    try:
        return _EXECUTORS[spec]()
    except KeyError:
        raise ValueError(
            f"unknown executor {spec!r}; available: {available()}") from None
