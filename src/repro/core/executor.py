"""Pluggable client execution: how one round's sampled clients are trained.

The FL loop (``repro.core.fl_loop``) is algorithm-agnostic; this module makes
it *execution*-agnostic too.  A ``ClientExecutor`` consumes the round inputs
(global params, broadcast payload, per-client states and data shards) and
produces the round outputs (uploads, weights, local losses, new states) —
how the clients actually run is its business:

    SequentialExecutor   one jitted lax.scan per client, Python loop over
                         clients — the reference semantics
    VmapExecutor         pad/stack the sampled clients' batches and vmap the
                         SAME scan so one jitted XLA call trains every
                         client in parallel
    ShardMapExecutor     VmapExecutor whose stacked computation is routed
                         through a "clients" device mesh with shard_map
                         (the repro/launch path); falls back to plain vmap
                         when the device count does not divide the cohort

All three consume identical materialized batches (one shared host-RNG draw,
same order as the historical per-client iterator), so sequential and vmap
outputs agree to float-associativity (~1e-6 on the paper's small models).

Masking rules for ragged clients (see ``repro.core.client``):
  * every batch within a client has a uniform size ``min(B, n_k)``; across
    clients batches are zero-padded to the cohort max with a per-example
    mask that zero-weights pads inside the loss — exact, not approximate;
  * clients with fewer steps than the cohort max get whole padded steps
    masked out as identities on (params, opt_state).

The ``precompute_aux`` stage
----------------------------
KD-family algorithms distill from teachers that are FROZEN for the whole
round (FedGKD Eq. 4-5, FedDistill's label table), so their per-example
teacher tensors are round constants.  Executors therefore invoke
``Algorithm.precompute_aux(model, payload, x, y, mask)`` ONCE per round on
each client's full shard — a single jitted, inference-only batched forward,
``(K, N_max, ...) -> (K, N_max, C)`` on the stacked path — and gather the
per-batch rows through the ``MaterializedClient.picks`` indices before the
training scan.  The gathered pytree reaches ``loss_fn`` as ``aux``; the
teacher's parameters never enter the differentiated (vmapped) closure.

Contract for ``precompute_aux`` implementations:
  * PURE pytree-in/pytree-out and vmappable over a stacked client axis —
    no Python-side branching on data values;
  * FIXED output pytree structure for a given algorithm: the choice of
    "aux vs no aux" is made per-RoundContext, never per-client or
    per-round, so compiled executables are reused across rounds;
  * inference-only: executors call it outside autodiff and treat the
    result as a constant of the round (accumulate in fp32 — the result
    feeds a loss whose gradients must match the inline recomputation);
  * ``mask`` is the per-example validity vector of the padded shard;
    rows with ``mask == 0`` may contain arbitrary values — consumers see
    them only through batch gathers that the example mask zero-weights;
  * returning ``None`` (the base-class default) disables the stage.

Cross-round caching: when the aux decomposes into independently versioned
parts (``Algorithm.precompute_parts`` — FedGKD-VOTE's M buffered teachers,
of which a round replaces exactly one), the batched executors cache each
part's per-example output under ``(client_id, version_key)`` in
``RoundContext.aux_cache`` and recompute only parts with unseen keys, so
steady-state teacher inference is ~1 shard forward per round instead of M.
Requires the caller to pass stable ``client_ids`` to ``run_round``; cached
values must be bit-reproducible from (part payload, shard) alone.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client as client_lib
from repro.core.algorithms import Algorithm
from repro.core.modelzoo import ModelBundle
from repro.data.pipeline import ClientData
from repro.optim import Optimizer


# ---------------------------------------------------------------------------
# round inputs/outputs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RoundContext:
    """Everything fixed across rounds that an executor needs."""
    algo: Algorithm
    model: ModelBundle
    opt: Optimizer
    lr: float
    batch_size: int
    epochs: int
    max_batches: Optional[int] = None
    precompute: bool = True   # False forces the inline (no-aux) loss path

    def __post_init__(self):
        loss_fn = self.algo.loss_fn(self.model)
        # scan-based whole-client pass (vmap/shard_map paths)
        self.local_update = client_lib.make_local_update(loss_fn, self.opt)
        # per-batch step (sequential path: compiles once per batch SHAPE
        # rather than once per (steps, batch) pair like the scan would)
        self.step = client_lib.make_step(loss_fn, self.opt, jit=True)
        # jitted-artifact cache owned by THIS context (executors must not
        # key a shared cache on id(ctx): the id can be reused after gc and
        # serve another algorithm's compiled round function)
        self.jit_cache: dict = {}
        # hooks left at the Algorithm defaults are no-ops — executors skip
        # the (host + dispatch) work of calling them entirely
        cls = type(self.algo)
        self.has_finalize = cls.client_finalize is not Algorithm.client_finalize
        self.has_state_update = (
            cls.update_client_state is not Algorithm.update_client_state)
        self.has_precompute = (
            self.precompute
            and cls.precompute_aux is not Algorithm.precompute_aux)
        # cross-round cache of per-(client, part-version) precompute outputs
        # (see "The precompute_aux stage" in the module docstring)
        self.aux_cache: dict = {}


@dataclasses.dataclass
class RoundResult:
    """Stacked-back-to-lists round outputs; shapes match the historical
    sequential loop so server_update / privacy / History are untouched."""
    uploads: list[dict]
    weights: list[float]
    local_losses: list[float]
    client_states: list[Any]


@runtime_checkable
class ClientExecutor(Protocol):
    name: str

    def run_round(self, ctx: RoundContext, global_params: Any, payload: Any,
                  client_states: list[Any], client_data: list[ClientData],
                  rng: np.random.Generator,
                  client_ids: Optional[list[int]] = None) -> RoundResult:
        """``client_ids`` (stable per-client identifiers, aligned with
        ``client_data``) unlock the cross-round teacher-logit cache for
        algorithms that expose ``precompute_parts``; ``None`` disables
        caching but changes nothing else."""
        ...


# ---------------------------------------------------------------------------
# batch materialization (shared by all executors)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MaterializedClient:
    xs: np.ndarray      # (S_k, bs_k, ...)
    ys: np.ndarray      # (S_k, bs_k)
    n: int              # true example count (aggregation weight)
    picks: np.ndarray   # (S_k, bs_k) int32 — shard-row index of each example


def materialize_client(rng: np.random.Generator, data: ClientData,
                       batch_size: int, epochs: int,
                       max_batches: Optional[int] = None) -> MaterializedClient:
    """Draw the client's epoch batches up front.

    Consumes ``rng`` exactly like the historical lazy ``batch_iterator``
    (one permutation per *started* epoch, partial batches wrap-padded), so
    a given seed yields the same batch sequence under every executor.
    ``picks`` records each batch example's row in the client shard so that
    round-level precomputed per-example tensors can be gathered per batch.
    """
    n = data.n
    bs = min(batch_size, n)
    picks: list[np.ndarray] = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, bs):
            idx = order[i:i + bs]
            if len(idx) < bs:               # wrap the final partial batch
                idx = np.concatenate([idx, order[: bs - len(idx)]])
            picks.append(idx)
            if max_batches is not None and len(picks) >= max_batches:
                break
        if max_batches is not None and len(picks) >= max_batches:
            break
    sel = np.stack(picks).astype(np.int32)  # (S_k, bs_k)
    return MaterializedClient(data.x[sel], data.y[sel], n, sel)


def _pad_and_stack(mats: list[MaterializedClient]):
    """(K, S, B, ...) arrays + example mask (K, S, B) + pick indices
    (K, S, B) + step mask (K, S).  Padded picks point at row 0 — harmless,
    the example mask zero-weights whatever they gather."""
    S = max(m.xs.shape[0] for m in mats)
    B = max(m.xs.shape[1] for m in mats)
    k = len(mats)
    feat = mats[0].xs.shape[2:]
    xs = np.zeros((k, S, B) + feat, mats[0].xs.dtype)
    ys = np.zeros((k, S, B), mats[0].ys.dtype)
    ex_mask = np.zeros((k, S, B), np.float32)
    picks = np.zeros((k, S, B), np.int32)
    step_mask = np.zeros((k, S), bool)
    for i, m in enumerate(mats):
        s, b = m.xs.shape[:2]
        xs[i, :s, :b] = m.xs
        ys[i, :s, :b] = m.ys
        ex_mask[i, :s, :b] = 1.0
        picks[i, :s, :b] = m.picks
        step_mask[i, :s] = True
    return (jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ex_mask),
            jnp.asarray(picks), jnp.asarray(step_mask))


def _pad_full_data(client_data: list[ClientData], cache: Optional[dict] = None,
                   cohort_key=None):
    """Stack each client's FULL shard to (K, N_max, ...) + mask for the
    vmapped ``client_finalize`` / ``precompute_aux`` hooks.

    Shards are immutable across rounds, so with ``cache``/``cohort_key``
    (the sampled client-id tuple) a repeated cohort skips the host padding
    work entirely.  The cache holds ONE entry: only a cohort repeated
    back-to-back (fixed-cohort loops, benchmarks) ever hits — under random
    partial participation every round keys differently, and retaining
    misses would pin (K, N_max, ...) device stacks for nothing."""
    if cache is not None and cohort_key is not None:
        hit = cache.get(cohort_key)
        if hit is not None:
            return hit
    n_max = max(d.n for d in client_data)
    k = len(client_data)
    feat = client_data[0].x.shape[1:]
    xs = np.zeros((k, n_max) + feat, client_data[0].x.dtype)
    ys = np.zeros((k, n_max), client_data[0].y.dtype)
    mask = np.zeros((k, n_max), np.float32)
    for i, d in enumerate(client_data):
        xs[i, :d.n] = d.x
        ys[i, :d.n] = d.y
        mask[i, :d.n] = 1.0
    out = jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)
    if cache is not None and cohort_key is not None:
        cache.clear()                   # single-entry: no device-array pile
        cache[cohort_key] = out
    return out


def tree_stack(trees: list[Any]) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree: Any, k: int) -> list[Any]:
    return [jax.tree_util.tree_map(lambda l: l[i], tree) for i in range(k)]


@functools.partial(jax.jit, static_argnums=1)
def _tree_unstack_jit(tree: Any, k: int) -> list[Any]:
    """tree_unstack as ONE dispatch (eager per-leaf slicing costs ~K·L tiny
    device ops per round, which dominates small-model rounds)."""
    return tree_unstack(tree, k)


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class SequentialExecutor:
    """Reference implementation: clients one at a time, one jitted step per
    batch (the historical loop — no padding, no masks)."""

    name = "sequential"

    def _precompute_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("precompute_seq")
        if fn is None:
            def stage(payload, x, y, mask, picks):
                aux = ctx.algo.precompute_aux(ctx.model, payload, x, y, mask)
                # gather every step's batch rows in one dispatch: (S, B, ...)
                return jax.tree_util.tree_map(lambda l: l[picks], aux)

            fn = jax.jit(stage)
            ctx.jit_cache["precompute_seq"] = fn
        return fn

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng, client_ids=None) -> RoundResult:
        uploads, weights, losses, new_states = [], [], [], []
        for state, cdata in zip(client_states, client_data):
            mat = materialize_client(rng, cdata, ctx.batch_size, ctx.epochs,
                                     ctx.max_batches)
            if ctx.has_precompute:
                # one jitted (precompute + all-steps gather) dispatch, then
                # cheap per-step numpy views — never per-step device slicing
                gathered = self._precompute_fn(ctx)(
                    payload, jnp.asarray(cdata.x), jnp.asarray(cdata.y),
                    jnp.ones((cdata.n,), jnp.float32), jnp.asarray(mat.picks))
                aux_steps = jax.tree_util.tree_map(np.asarray, gathered)
            params, opt_state = global_params, ctx.opt.init(global_params)
            step_losses = []
            for s in range(mat.xs.shape[0]):
                aux_b = (jax.tree_util.tree_map(lambda l: l[s], aux_steps)
                         if ctx.has_precompute else ())
                params, opt_state, loss, _ = ctx.step(
                    params, opt_state, payload, state,
                    jnp.asarray(mat.xs[s]), jnp.asarray(mat.ys[s]), None,
                    aux_b, ctx.lr)
                step_losses.append(float(loss))
            extras = {}
            if ctx.has_finalize:
                extras = ctx.algo.client_finalize(
                    ctx.model, params, jnp.asarray(cdata.x),
                    jnp.asarray(cdata.y), jnp.ones((cdata.n,), jnp.float32),
                    payload)
            new_states.append(
                ctx.algo.update_client_state(state, params, payload)
                if ctx.has_state_update else state)
            uploads.append({"params": params, **extras})
            weights.append(float(mat.n))
            losses.append(float(np.mean(step_losses)) if step_losses else 0.0)
        return RoundResult(uploads, weights, losses, new_states)


class VmapExecutor:
    """One jitted call per round: vmap the per-client scan over a stacked
    client axis.  Wall-clock stops scaling linearly with participation."""

    name = "vmap"

    # -- cached jitted stages (cache lives on ctx, see RoundContext) -----
    def _round_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("round")
        if fn is None:
            fn = jax.jit(jax.vmap(ctx.local_update,
                                  in_axes=(None, None, 0, 0, 0, 0, 0, 0,
                                           None)))
            ctx.jit_cache["round"] = fn
        return fn

    def _precompute_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("precompute")
        if fn is None:
            def stage(payload, fx, fy, fmask):
                # one inference-only batched forward over every client's
                # full shard: (K, N_max, ...) -> per-example aux leaves
                return jax.vmap(
                    lambda x, y, m: ctx.algo.precompute_aux(
                        ctx.model, payload, x, y, m))(fx, fy, fmask)

            fn = jax.jit(stage)
            ctx.jit_cache["precompute"] = fn
        return fn

    def _gather_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("gather")
        if fn is None:
            # per-batch rows: leaves (K, N_max, ...) -> (K, S, B, ...)
            fn = jax.jit(jax.vmap(lambda a, p: jax.tree_util.tree_map(
                lambda l: l[p], a)))
            ctx.jit_cache["gather"] = fn
        return fn

    def _finalize_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("finalize")
        if fn is None:
            def one(params, x, y, mask, payload):
                return ctx.algo.client_finalize(ctx.model, params, x, y,
                                                mask, payload)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 0, None)))
            ctx.jit_cache["finalize"] = fn
        return fn

    def _state_fn(self, ctx: RoundContext) -> Callable:
        fn = ctx.jit_cache.get("state")
        if fn is None:
            def one(state, params, payload):
                return ctx.algo.update_client_state(state, params, payload)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, None)))
            ctx.jit_cache["state"] = fn
        return fn

    # -- the stacked computation (ShardMapExecutor overrides this) -------
    def _execute(self, ctx, global_params, payload, states_stacked,
                 xs, ys, ex_mask, aux, step_mask):
        return self._round_fn(ctx)(global_params, payload, states_stacked,
                                   xs, ys, ex_mask, aux, step_mask, ctx.lr)

    def _incremental_aux(self, ctx: RoundContext, payload, parts_spec,
                         client_ids, client_data, full):
        """Cross-round cached precompute: recompute only the parts whose
        version key is new for a sampled client (steady state: ONE teacher
        forward over the stacked cohort per round instead of M), then
        combine.  Missing parts are computed on the stacked (K, N_max)
        shard — one dispatch per missing version, never per client."""
        keys, get_part = parts_spec
        cohort = tuple(client_ids)
        part_fn = ctx.jit_cache.get("part")
        if part_fn is None:
            part_fn = jax.jit(jax.vmap(
                lambda pp, x: ctx.algo.precompute_part(ctx.model, pp, x),
                in_axes=(None, 0)))
            ctx.jit_cache["part"] = part_fn
        fx = full[0]
        for cid in client_ids:
            ctx.aux_cache.setdefault(cid, {})

        stacked_by_key: dict = {}       # freshly computed parts, deduped

        def ensure_stacked(m, key):
            if key not in stacked_by_key:
                stacked_by_key[key] = part_fn(get_part(m), fx)  # (K, N_max, .)
            return stacked_by_key[key]

        # fill the per-client numpy cache for any (client, version) misses
        for m, key in enumerate(keys):
            if any(key not in ctx.aux_cache[cid] for cid in client_ids):
                arr = np.asarray(ensure_stacked(m, key))
                for i, (cid, d) in enumerate(zip(client_ids, client_data)):
                    if key not in ctx.aux_cache[cid]:
                        ctx.aux_cache[cid][key] = arr[i, :d.n]

        # per-VERSION device slabs (K, N_max, ...): version keys ROTATE
        # positions every round, so the cache must be keyed by version, not
        # position — a repeated cohort then re-stacks M resident slabs and
        # uploads nothing but the one freshly computed part
        dev = ctx.jit_cache.get("parts_dev")
        if dev is None or dev["cohort"] != cohort:
            dev = {"cohort": cohort, "slabs": {}}
            ctx.jit_cache["parts_dev"] = dev
        slabs = dev["slabs"]
        k = len(client_data)
        n_max = max(d.n for d in client_data)
        tail = ctx.aux_cache[client_ids[0]][keys[0]].shape[1:]
        for m, key in enumerate(keys):
            if key in slabs:
                continue
            if key in stacked_by_key:       # freshly computed, already (K,N)
                slabs[key] = stacked_by_key[key]
            else:                           # host assembly of ONE part only
                buf = np.zeros((k, n_max) + tail, np.float32)
                for i, (cid, d) in enumerate(zip(client_ids, client_data)):
                    buf[i, :d.n] = ctx.aux_cache[cid][key]
                slabs[key] = jnp.asarray(buf)
        parts = jnp.stack([slabs[key] for key in keys])   # (P, K, N_max, ..)
        # evict versions that rotated out of the part key set
        keyset = set(keys)
        dev["slabs"] = {kk: v for kk, v in slabs.items() if kk in keyset}
        for cid in client_ids:
            ctx.aux_cache[cid] = {kk: v for kk, v in ctx.aux_cache[cid].items()
                                  if kk in keyset}
        combine_fn = ctx.jit_cache.get("combine")
        if combine_fn is None:
            combine_fn = jax.jit(jax.vmap(
                lambda pl, pr, x, y, msk: ctx.algo.precompute_combine(
                    pl, pr, x, y, msk),
                in_axes=(None, 1, 0, 0, 0)))
            ctx.jit_cache["combine"] = combine_fn
        return combine_fn(payload, jnp.asarray(parts), *full)

    def run_round(self, ctx, global_params, payload, client_states,
                  client_data, rng, client_ids=None) -> RoundResult:
        k = len(client_data)
        full = None
        aux_full = None
        if ctx.has_precompute or ctx.has_finalize:
            full = _pad_full_data(
                client_data, cache=ctx.jit_cache.setdefault("full_data", {}),
                cohort_key=(tuple(client_ids)
                            if client_ids is not None else None))
        if ctx.has_precompute:
            parts_spec = (ctx.algo.precompute_parts(payload)
                          if client_ids is not None else None)
            if parts_spec is not None:
                aux_full = self._incremental_aux(ctx, payload, parts_spec,
                                                 client_ids, client_data,
                                                 full)
            else:
                # dispatch the (async) teacher forward FIRST: it needs no
                # batch picks, so the device crunches it while the host
                # materializes and pads the round's batches below
                aux_full = self._precompute_fn(ctx)(payload, *full)

        mats = [materialize_client(rng, d, ctx.batch_size, ctx.epochs,
                                   ctx.max_batches) for d in client_data]
        xs, ys, ex_mask, picks, step_mask = _pad_and_stack(mats)
        states_stacked = tree_stack(client_states)
        aux = (self._gather_fn(ctx)(aux_full, picks)
               if ctx.has_precompute else ())

        params_stacked, mloss = self._execute(
            ctx, global_params, payload, states_stacked, xs, ys, ex_mask,
            aux, step_mask)

        if ctx.has_finalize:
            fx, fy, fmask = full
            extras_stacked = self._finalize_fn(ctx)(params_stacked, fx, fy,
                                                    fmask, payload)
        else:
            extras_stacked = {}
        if ctx.has_state_update:
            new_states_stacked = self._state_fn(ctx)(states_stacked,
                                                     params_stacked, payload)
        else:
            new_states_stacked = None

        per_client = _tree_unstack_jit(
            (params_stacked, extras_stacked), k)
        uploads = [{"params": p, **e} for p, e in per_client]
        new_states = (_tree_unstack_jit(new_states_stacked, k)
                      if ctx.has_state_update else list(client_states))
        return RoundResult(uploads, [float(m.n) for m in mats],
                           np.asarray(mloss).astype(float).tolist(),
                           new_states)


class ShardMapExecutor(VmapExecutor):
    """Route the stacked round through a ``("clients",)`` device mesh.

    Experimental stub for the multi-device path (repro/launch idiom): each
    shard vmaps its slice of the cohort with no cross-client collectives;
    outputs stay client-stacked.  Requires the sampled-cohort size to be a
    multiple of the device count — otherwise it silently degrades to the
    single-device vmap computation.
    """

    name = "shard_map"

    def _execute(self, ctx, global_params, payload, states_stacked,
                 xs, ys, ex_mask, aux, step_mask):
        from jax.sharding import PartitionSpec as P

        from repro.sharding import shard_map_compat

        ndev = len(jax.devices())
        k = xs.shape[0]
        if ndev == 1 or k % ndev != 0:
            return super()._execute(ctx, global_params, payload,
                                    states_stacked, xs, ys, ex_mask, aux,
                                    step_mask)

        key = ("smap", ndev)
        jfn = ctx.jit_cache.get(key)
        if jfn is None:
            mesh = jax.make_mesh((ndev,), ("clients",))
            inner = jax.vmap(ctx.local_update,
                             in_axes=(None, None, 0, 0, 0, 0, 0, 0, None))
            fn = shard_map_compat(
                lambda gp, pl, st, a, b, c, x, d: inner(gp, pl, st, a, b, c,
                                                        x, d, ctx.lr),
                mesh,
                in_specs=(P(), P(), P("clients"), P("clients"), P("clients"),
                          P("clients"), P("clients"), P("clients")),
                out_specs=(P("clients"), P("clients")))
            jfn = jax.jit(fn)
            ctx.jit_cache[key] = jfn
        return jfn(global_params, payload, states_stacked, xs, ys,
                   ex_mask, aux, step_mask)


# ---------------------------------------------------------------------------
# registry / resolution
# ---------------------------------------------------------------------------

_EXECUTORS = {
    "sequential": SequentialExecutor,
    "vmap": VmapExecutor,
    "shard_map": ShardMapExecutor,
}


def available() -> list[str]:
    return sorted(_EXECUTORS) + ["auto"]


def get_executor(spec: "str | ClientExecutor", algo: Algorithm,
                 n_sample: int,
                 model: Optional[ModelBundle] = None) -> ClientExecutor:
    """Resolve an executor spec.

    ``"auto"`` picks the batched vmap path when the algorithm declares
    ``supports_vmap``, more than one client is sampled per round, AND the
    model's ops lower well under stacked-weight vmap (``vmap_friendly`` —
    dense models yes, conv backbones on CPU no); otherwise the sequential
    reference.  Instances pass through unchanged.
    """
    if not isinstance(spec, str):
        return spec
    if spec == "auto":
        batched_ok = (getattr(algo, "supports_vmap", False) and n_sample > 1
                      and (model is None or model.vmap_friendly))
        spec = "vmap" if batched_ok else "sequential"
    try:
        return _EXECUTORS[spec]()
    except KeyError:
        raise ValueError(
            f"unknown executor {spec!r}; available: {available()}") from None
