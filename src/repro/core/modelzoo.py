"""Uniform classifier bundles for the paper's tasks.

A ``ModelBundle`` exposes init/apply/features so every FL algorithm (some
need penultimate features: MOON; some only logits) can drive any backbone —
ResNet-8/50, the DistilBERT-class text encoder, or the toy MLP.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.paper import PaperTask, distilbert_class_config
from repro.models import layers, resnet, transformer


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    name: str
    init: Callable              # (rng) -> params
    apply: Callable             # (params, x) -> logits (B, C)
    features: Callable          # (params, x) -> penultimate features (B, F)
    has_projection_head: bool = False
    # whether vmapping the model over stacked per-client WEIGHTS lowers well
    # (dense stacks become batched GEMMs; batched-weight convs lower poorly
    # on CPU backends) — consulted by executor="auto"
    vmap_friendly: bool = True
    # whether apply/features consume CLIENT-STACKED params natively
    # (leading cohort axis; convs route through kernels.grouped_conv) —
    # with an algorithm that provides ``batched_loss_fn`` this unlocks the
    # batched executors' fused client-batched round body
    client_batched: bool = False


def _text_classifier(task: PaperTask, projection_head: bool) -> ModelBundle:
    cfg = distilbert_class_config(task)

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        p = {"backbone": transformer.init(k1, cfg),
             "fc": layers.dense_bias_init(k2, cfg.d_model, task.num_classes)}
        if projection_head:
            p["proj_head"] = {
                "fc1": layers.dense_bias_init(k3, cfg.d_model, cfg.d_model),
                "fc2": layers.dense_bias_init(
                    jax.random.fold_in(k3, 1), cfg.d_model, 256)}
            p["fc"] = layers.dense_bias_init(k2, 256, task.num_classes)
        return p

    def features(params, x):
        h, _ = transformer.hidden_states(params["backbone"], cfg, x)
        h = jnp.mean(h, axis=1)                     # mean-pool over tokens
        if "proj_head" in params:
            h = jax.nn.relu(layers.dense(params["proj_head"]["fc1"], h))
            h = layers.dense(params["proj_head"]["fc2"], h)
        return h

    def apply(params, x):
        return layers.dense(params["fc"], features(params, x))

    return ModelBundle(f"distilbert-{task.name}", init, apply, features,
                       projection_head, vmap_friendly=False)


def make_model(task: PaperTask, projection_head: bool = False,
               width: int = 16) -> ModelBundle:
    """Build the paper's backbone for a task (+ optional MOON/FedGKD+ head)."""
    if task.model == "resnet8":
        return ModelBundle(
            "resnet8",
            lambda rng: resnet.resnet8_init(rng, task.num_classes, width=width,
                                            projection_head=projection_head),
            resnet.resnet8_apply, resnet.resnet8_features, projection_head,
            vmap_friendly=False, client_batched=True)
    if task.model == "resnet50":
        return ModelBundle(
            "resnet50",
            lambda rng: resnet.resnet50_init(rng, task.num_classes,
                                             projection_head=projection_head),
            resnet.resnet50_apply, resnet.resnet50_features, projection_head,
            vmap_friendly=False, client_batched=True)
    if task.model == "mlp":
        h = 4 * width                    # width=16 default -> [64, 64]
        return ModelBundle(
            "mlp",
            lambda rng: resnet.mlp_init(rng, task.feat_dim, [h, h],
                                        task.num_classes),
            resnet.mlp_apply, resnet.mlp_features, False)
    if task.model == "distilbert":
        return _text_classifier(task, projection_head)
    raise ValueError(task.model)
