"""Learning-rate schedules (callables step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.0):
    def f(step):
        warm = lr * step / max(1, warmup_steps)
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                     0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return f
