from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, adam, apply_updates, global_norm, clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
