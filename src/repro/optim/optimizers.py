"""Minimal functional optimizers (optax is not available offline).

An ``Optimizer`` is (init, update):
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)
    params = apply_updates(params, updates)
Updates are NEGATIVE steps (add them to params).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = object


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return _tmap(lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
                 params, updates)


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return _tmap(lambda g: g * scale, grads)


def sgd(momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False, state_dtype=None) -> Optimizer:
    """SGD with (optional) heavyweight momentum and decoupled weight decay —
    the paper's CV optimizer (momentum 0.9, wd 1e-5)."""

    def init(params):
        if momentum == 0.0:
            return ()
        return _tmap(lambda p: jnp.zeros_like(
            p, dtype=state_dtype or p.dtype), params)

    def update(grads, state, params, lr):
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p.astype(g.dtype),
                          grads, params)
        if momentum == 0.0:
            return _tmap(lambda g: -lr * g, grads), ()
        new_m = _tmap(lambda m, g: momentum * m.astype(g.dtype) + g, state, grads)
        if nesterov:
            step = _tmap(lambda g, m: g + momentum * m, grads, new_m)
        else:
            step = new_m
        new_m = _tmap(lambda m, s: m.astype(s.dtype), new_m, state)
        return _tmap(lambda s: -lr * s, step), new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jax.Array


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """Adam(W) — the paper's NLP optimizer (lr 1e-5, wd 0)."""

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=state_dtype)
        return AdamState(_tmap(z, params), _tmap(z, params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state.count + 1
        gf = _tmap(lambda g: g.astype(state_dtype), grads)
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state.mu, gf)
        nu = _tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, gf)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        step = _tmap(lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        if weight_decay:
            step = _tmap(lambda s, p: s + weight_decay * p.astype(s.dtype),
                         step, params)
        return _tmap(lambda s: -lr * s, step), AdamState(mu, nu, count)

    return Optimizer(init, update)
