"""mixtral-8x7b [moe] — arXiv:2401.04088.

32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert, vocab=32000,
8 experts top-2, sliding-window attention (4096).  Softmax-over-top-k gates.
SWA makes long_500k decode feasible (bounded ring KV cache).
"""
from repro.configs import base
from repro.models.config import ModelConfig
from repro.models.moe import MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000, head_dim=128,
        attn_window=4096, rope_theta=1e6,
        moe=MoEConfig(d_model=4096, d_ff=14336, n_experts=8, top_k=2,
                      router_type="softmax"),
        norm="rms", act="swiglu", tie_embeddings=False,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("mixtral-8x7b", full, smoke)
