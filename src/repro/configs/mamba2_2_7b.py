"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

64L d_model=2560, attention-free, d_ff=0, vocab=50280, ssm_state=128,
head_dim=64, expand=2.  Sub-quadratic: long_500k decode runs (O(1) state).
FedGKD applies unchanged — the KD regularizer is logit-space.
"""
from repro.configs import base
from repro.models.config import ModelConfig
from repro.models.ssm import SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280, attn_type="none",
        ssm=SSMConfig(d_model=2560, d_state=128, head_dim=64, expand=2,
                      d_conv=4, chunk=256),
        norm="rms", tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("mamba2-2.7b", full, smoke)
