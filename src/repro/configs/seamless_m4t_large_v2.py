"""seamless-m4t-large-v2 [audio, enc-dec] — arXiv:2308.11596.

24L d_model=1024 16H (GQA kv=16 == MHA) d_ff=8192 vocab=256206.
Backbone only: the w2v-BERT speech codec is STUBBED — the encoder consumes
precomputed frame embeddings (frontends.AUDIO_FRAMES per clip).  24 encoder
layers + 24 text-decoder layers (model card geometry).  LayerNorm + GELU as
in the original transformer stack; RoPE substituted for sinusoidal positions
(TPU adaptation; noted in DESIGN.md).
"""
from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206, head_dim=64,
        enc_layers=24, frontend="audio",
        norm="ln", act="gelu", tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("seamless-m4t-large-v2", full, smoke)
