"""Architecture configs: the 10 assigned architectures + the paper's own.

``get_config(name)`` returns the full production ModelConfig;
``get_smoke_config(name)`` the reduced same-family variant used in CPU tests.
"""
from repro.configs.base import (  # noqa: F401
    ALL_ARCHS, SHAPES, InputShape, get_config, get_smoke_config, input_specs,
    list_archs, register, train_input_specs, decode_input_specs,
)

# import for registration side effects
from repro.configs import (  # noqa: F401
    seamless_m4t_large_v2, minitron_4b, granite_34b, mixtral_8x7b,
    phi4_mini_3_8b, internlm2_20b, mamba2_2_7b, deepseek_v3_671b,
    zamba2_1_2b, llava_next_34b,
)
