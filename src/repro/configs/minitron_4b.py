"""minitron-4b [dense] — arXiv:2407.14679 (pruned Nemotron-4 15B).

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, head_dim=128.
Nemotron uses squared-ReLU MLPs; we keep the framework's SwiGLU MLP at the
same d_ff (same FLOP class — noted in DESIGN.md).
"""
from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab_size=256000, head_dim=128,
        rope_theta=10000.0, norm="rms", act="swiglu", tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("minitron-4b", full, smoke)
