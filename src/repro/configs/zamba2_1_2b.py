"""zamba2-1.2b [hybrid] — arXiv:2411.15242.

38 Mamba2 layers (d_model=2048, ssm_state=64) + a SHARED attention+MLP block
(32H, kv=32, d_ff=8192) applied every 6 Mamba layers, consuming
[h ; embedding-stream] (the Zamba re-injection trick).  Sub-quadratic
backbone -> long_500k decode runs.
"""
from repro.configs import base
from repro.models.config import ModelConfig
from repro.models.ssm import SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        ssm=SSMConfig(d_model=2048, d_state=64, head_dim=64, expand=2,
                      d_conv=4, chunk=256),
        shared_attn_period=6,
        norm="rms", act="swiglu", tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("zamba2-1.2b", full, smoke)
