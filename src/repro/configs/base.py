"""Config registry + the 4 assigned input shapes + ShapeDtypeStruct specs.

The FULL configs are exercised only via ``launch/dryrun.py`` (lower+compile,
no allocation); functional tests instantiate ``get_smoke_config`` variants
(≤2 layers, d_model≤512, ≤4 experts) and run a real step on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import frontends, transformer


class InputShape(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    mode: str               # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}

ALL_ARCHS = [
    "seamless-m4t-large-v2", "minitron-4b", "granite-34b", "mixtral-8x7b",
    "phi4-mini-3.8b", "internlm2-20b", "mamba2-2.7b", "deepseek-v3-671b",
    "zamba2-1.2b", "llava-next-34b",
]


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str) -> ModelConfig:
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    return _SMOKE[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def _frontend_len(cfg: ModelConfig) -> int:
    return cfg.frontend_seq or (frontends.frontend_seq(cfg.frontend)
                                if cfg.frontend else 0)


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Inputs for train_step / prefill: {tokens, labels[, frontend/enc emb]}."""
    b, s = shape.global_batch, shape.seq_len
    adt = cfg.adtype
    specs: dict = {}
    if cfg.enc_layers:
        # enc-dec: encoder consumes frontend frame embeddings, decoder `s` toks
        specs["enc_embeddings"] = jax.ShapeDtypeStruct(
            (b, _frontend_len(cfg), cfg.d_model), adt)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    if cfg.frontend:
        fl = _frontend_len(cfg)
        specs["frontend_embeddings"] = jax.ShapeDtypeStruct((b, fl, cfg.d_model), adt)
        s_text = s - fl
        assert s_text > 0, f"{cfg.name}: seq {s} too short for frontend {fl}"
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape,
                       cache_dtype=jnp.bfloat16) -> dict:
    """Inputs for serve_step: one new token + a seq_len KV/SSM cache."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s, cache_dtype))
    specs["cache"] = cache
    if cfg.enc_layers:
        specs["enc_out"] = jax.ShapeDtypeStruct(
            (b, _frontend_len(cfg), cfg.d_model), cfg.adtype)
    return specs


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.mode == "decode":
        return decode_input_specs(cfg, shape)
    return train_input_specs(cfg, shape)


# ---------------------------------------------------------------------------
# smoke reduction helper
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a full config to the same-family smoke variant:
    ≤2 layers, d_model≤256, ≤4 experts, small vocab, fp32."""
    kw: dict = dict(
        n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=32, d_ff=256, vocab_size=503,  # odd-ish to catch padding bugs
        param_dtype="float32", activation_dtype="float32",
        remat=False, scan_layers=True, use_pallas=False,
        first_k_dense=min(cfg.first_k_dense, 1),
        enc_layers=2 if cfg.enc_layers else 0,
        frontend_seq=16 if cfg.frontend else 0,
        moe_group_size=64,
        attn_window=min(cfg.attn_window, 8) if cfg.attn_window else None,
    )
    if cfg.moe is not None:
        kw["moe"] = cfg.moe._replace(
            d_model=128, d_ff=64, n_experts=4,
            top_k=min(cfg.moe.top_k, 2), group_size=64,
            shared_d_ff=64 if cfg.moe.shared_d_ff else 0)
    if cfg.mla is not None:
        kw["mla"] = cfg.mla._replace(
            d_model=128, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
            qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = cfg.ssm._replace(d_model=128, d_state=16, head_dim=16,
                                     chunk=16)
        kw["n_layers"] = 4 if cfg.shared_attn_period else 2
    if cfg.shared_attn_period:
        kw["shared_attn_period"] = 2
    if cfg.mtp_depth:
        kw["mtp_depth"] = cfg.mtp_depth
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
