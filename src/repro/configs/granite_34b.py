"""granite-34b [dense, code] — arXiv:2405.04324 (Granite Code 34B).

88L d_model=6144 48H (MQA: kv=1) d_ff=24576 vocab=49152.
GPTBigCode-style: LayerNorm + GELU, multi-query attention.  The original
uses learned absolute positions; we use RoPE (TPU-idiomatic; DESIGN.md).
"""
from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152, head_dim=128,
        norm="ln", act="gelu", qkv_bias=True, tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("granite-34b", full, smoke)
