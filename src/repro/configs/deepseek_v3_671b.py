"""deepseek-v3-671b [moe] — arXiv:2412.19437.

61L d_model=7168, MLA with 128 heads (nope 128 + rope 64, v 128;
q_lora 1536, kv_lora 512), MoE: 1 shared + 256 routed experts top-8
(sigmoid router, per-expert d_ff=2048), first 3 layers dense (d_ff=18432),
vocab=129280, MTP depth 1.
"""
from repro.configs import base
from repro.models.attention import MLAConfig
from repro.models.config import ModelConfig
from repro.models.moe import MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432,  # dense-layer d_ff (first_k_dense)
        vocab_size=129280,
        attn_type="mla",
        mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                      kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                      n_shared_experts=1, shared_d_ff=2048,
                      router_type="sigmoid", capacity_factor=1.25),
        first_k_dense=3, mtp_depth=1,
        norm="rms", act="swiglu", tie_embeddings=False,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
        moe_group_size=4096,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("deepseek-v3-671b", full, smoke)
