"""The paper's own experimental configurations (Section 5.1).

Datasets are synthetic stand-ins with matched class counts (see
repro.data.synthetic; real CIFAR/AG-News are unavailable offline — DESIGN.md
§8).  Sizes are scaled by ``scale`` so CPU runs finish; the Dirichlet
non-IID machinery, client counts, participation ratios, local epochs and
hyper-parameters mirror the paper exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PaperTask:
    name: str
    kind: str                  # "image" | "text"
    model: str                 # "resnet8" | "resnet50" | "mlp" | "distilbert"
    num_classes: int
    train_size: int            # paper's training-set size
    n_clients: int
    rounds: int
    local_epochs: int
    participation: float       # C
    batch_size: int = 64
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 1e-5
    optimizer: str = "sgd"
    gamma: float = 0.2         # FedGKD distillation coefficient
    buffer_m: int = 5          # FedGKD(-VOTE) buffer
    image_hw: int = 32
    # text tasks
    seq_len: int = 64
    vocab_size: int = 2000
    d_model: int = 128
    # tabular (mlp) tasks
    feat_dim: int = 16


CIFAR10 = PaperTask("cifar10", "image", "resnet8", num_classes=10,
                    train_size=45_000, n_clients=20, rounds=100,
                    local_epochs=20, participation=0.2, gamma=0.2)
CIFAR100 = PaperTask("cifar100", "image", "resnet8", num_classes=100,
                     train_size=45_000, n_clients=20, rounds=100,
                     local_epochs=20, participation=0.2, gamma=0.2)
TINY_IMAGENET = PaperTask("tiny-imagenet", "image", "resnet50", num_classes=200,
                          train_size=90_000, n_clients=20, rounds=30,
                          local_epochs=20, participation=0.2, gamma=0.1,
                          image_hw=64)
AG_NEWS = PaperTask("ag-news", "text", "distilbert", num_classes=4,
                    train_size=60_000, n_clients=20, rounds=10,
                    local_epochs=1, participation=0.2, optimizer="adam",
                    lr=1e-5, weight_decay=0.0, gamma=0.2, buffer_m=3)
SST5 = PaperTask("sst5", "text", "distilbert", num_classes=5,
                 train_size=4_272, n_clients=10, rounds=10,
                 local_epochs=3, participation=0.4, optimizer="adam",
                 lr=1e-5, weight_decay=0.0, gamma=0.2, buffer_m=3)
# not from the paper: a light MLP workload for executor benchmarks/examples
TOY = PaperTask("toy", "tabular", "mlp", num_classes=10,
                train_size=2_000, n_clients=16, rounds=20,
                local_epochs=2, participation=0.5, batch_size=32,
                lr=0.05, weight_decay=0.0, feat_dim=16)

PAPER_TASKS = {t.name: t for t in (CIFAR10, CIFAR100, TINY_IMAGENET, AG_NEWS,
                                   SST5, TOY)}


def scaled(task: PaperTask, scale: float, rounds: Optional[int] = None,
           local_epochs: Optional[int] = None) -> PaperTask:
    """Shrink dataset size / rounds for CPU execution; everything else kept."""
    return dataclasses.replace(
        task,
        train_size=max(task.n_clients * 2 * task.num_classes,
                       int(task.train_size * scale)),
        rounds=rounds if rounds is not None else task.rounds,
        local_epochs=local_epochs if local_epochs is not None else task.local_epochs)


def distilbert_class_config(task: PaperTask) -> ModelConfig:
    """DistilBERT-class text encoder (6L, LN+GELU) used as a classifier
    backbone for the paper's NLP tasks (scaled width for CPU)."""
    return ModelConfig(
        name=f"distilbert-{task.name}", family="dense",
        n_layers=4, d_model=task.d_model, n_heads=4, n_kv_heads=4,
        d_ff=4 * task.d_model, vocab_size=task.vocab_size, head_dim=0,
        norm="ln", act="gelu", tie_embeddings=True,
        param_dtype="float32", activation_dtype="float32",
        scan_layers=True)
