"""phi4-mini-3.8b [dense] — arXiv:2412.08905.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064. RoPE + SwiGLU + GQA.
This arch also carries the beyond-paper long-context demonstration: a 4k
sliding-window override (`long_variant()`) that makes long_500k decode
feasible on a dense model (see DESIGN.md §Arch-applicability).
"""
from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab_size=200064, head_dim=128,
        norm="rms", act="swiglu", tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def long_variant() -> ModelConfig:
    return full().replace(attn_window=4096)


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("phi4-mini-3.8b", full, smoke)
