"""llava-next-34b [vlm] — hf:llava-hf/llava-v1.6 (34B = Yi-34B backbone).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  AnyRes tiling:
the SigLIP/CLIP tower + projector are STUBBED — input_specs provides
precomputed patch embeddings (576 base patches) prepended to the text
tokens; labels are text-only (loss masks frontend positions).
"""
from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab_size=64000, head_dim=128, rope_theta=5e6,
        frontend="vision",
        norm="rms", act="swiglu", tie_embeddings=False,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("llava-next-34b", full, smoke)
