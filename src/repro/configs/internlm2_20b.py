"""internlm2-20b [dense] — arXiv:2403.17297.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92544, head_dim=128, rope_theta=1e6,
        norm="rms", act="swiglu", tie_embeddings=False,
        param_dtype="bfloat16", activation_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelConfig:
    return base.reduce_for_smoke(full())


base.register("internlm2-20b", full, smoke)
