"""Launch layer: production meshes, the multi-pod dry-run, train/serve steps
and the shard_map federated driver."""
