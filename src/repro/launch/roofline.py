"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs / peak_FLOPs            [per-device program]
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / link_bw

cost_analysis()/the SPMD HLO are per-device, so no further /chips — the
formulas in the assignment divide global quantities by chips, which is the
same number.  MODEL_FLOPS uses 6·N·D (dense) or 6·N_active·D (MoE) with D =
global tokens per step; its per-device share is MODEL_FLOPS/chips.
"""
from __future__ import annotations

import dataclasses

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # per-device
    hlo_bytes: float                 # per-device
    collective_bytes: float          # per-device
    model_flops: float               # global 6·N·D (or 6·N_active·D)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs · chips) — remat/redundancy indicator."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(cfg, n_tokens: int, mode: str, *, with_teacher: bool = False,
                mtp: bool = False) -> float:
    """6·N·D training FLOPs (2·N·D forward-only for prefill/decode).

    N = active params; teacher forward adds +2·N·D when enabled."""
    n_active = cfg.active_param_count()
    mult = 6.0 if mode == "train" else 2.0
    total = mult * n_active * n_tokens
    if with_teacher:
        total += 2.0 * n_active * n_tokens
    if mtp and cfg.mtp_depth:
        # one extra block + head forward+backward per token (small)
        total *= 1.05
    return total
