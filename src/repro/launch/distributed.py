"""Real multi-process jax topologies for the multi-host federated loop.

The population placement layer (``repro.population.placement``) is
transport-only — two plain processes over a shared exchange dir already
train in lockstep.  This module is the step beyond the emulator: bring the
SAME processes up as one ``jax.distributed`` topology, so collectives,
``jax.process_count()``-aware mesh selection
(``executor.ShardMapExecutor`` shards each host's cohort slice over
``jax.local_devices()``) and the process-local global-array stitch
(``sharding.make_array_from_process_local_data_compat``'s non-fallback
branch) all run for real.

Typical 2-host CPU launch (each process forcing 2 host devices):

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
    python -m repro.launch.distributed \\
        --coordinator 127.0.0.1:<port> --num-processes 2 --process-id 0 &
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
    python -m repro.launch.distributed \\
        --coordinator 127.0.0.1:<port> --num-processes 2 --process-id 1

On CPU the cross-process collectives need the gloo backend
(``jax_cpu_collectives_implementation``); on TPU/GPU jax picks its native
transport and the knob is ignored.  ``initialize`` must run before any
other jax call touches the backend — first device access freezes the
topology.
"""
from __future__ import annotations

import socket
from typing import Optional


def find_free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (for the coordinator of test
    topologies; production launchers get the address from the scheduler)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def initialize(coordinator_address: str, num_processes: int,
               process_id: int, *,
               cpu_collectives: Optional[str] = "gloo") -> dict:
    """Bring this process up as one rank of a ``jax.distributed`` topology.

    Wraps ``jax.distributed.initialize`` with the one piece of setup a CPU
    topology needs — selecting the gloo collectives implementation — which
    must happen BEFORE the backend initializes.  Releases without the knob
    (or without gloo builds) just skip it: the shim degrades, it never
    blocks a real accelerator topology.

    Returns a summary dict (process index/count, local/global device
    counts) so launchers and tests can assert the topology they asked for
    actually came up.
    """
    import jax

    if cpu_collectives is not None:
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              cpu_collectives)
        except (AttributeError, ValueError):
            pass        # older jax: single-process CPU still works
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return {"process_id": jax.process_index(),
            "process_count": jax.process_count(),
            "local_devices": len(jax.local_devices()),
            "global_devices": len(jax.devices())}


def placement_from_runtime(exchange_dir: str, **kw):
    """A ``HostPlacement`` for THIS process's rank in the live topology.

    Call after ``initialize``: host identity then comes from the one
    source of truth (``jax.process_index`` / ``jax.process_count``)
    instead of being threaded through argv twice — a transposed rank
    would silently swap shard ownership between hosts."""
    import jax

    from repro.population.placement import HostPlacement

    return HostPlacement(jax.process_index(), jax.process_count(),
                         exchange_dir=exchange_dir, **kw)


def _smoke(args) -> int:
    """CLI smoke: initialize, psum a rank-tagged array across processes,
    verify every rank sees the same total.  Exit 0 = the topology works."""
    import numpy as np

    info = initialize(args.coordinator, args.num_processes, args.process_id,
                      cpu_collectives=args.cpu_collectives or None)
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_clients_mesh
    from repro.sharding import make_array_from_process_local_data_compat

    mesh = make_clients_mesh()
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("clients"))
    n_local = info["local_devices"]
    n_global = info["global_devices"]
    local = (np.arange(n_local, dtype=np.float32)
             + info["process_id"] * n_local)
    arr = make_array_from_process_local_data_compat(sharding, local,
                                                    (n_global,))
    total = float(jax.jit(jnp.sum)(arr))
    want = float(np.arange(n_global, dtype=np.float32).sum())
    print(f"[distributed] rank {info['process_id']}/{info['process_count']} "
          f"local_devices={n_local} global_devices={n_global} "
          f"sum={total} want={want}")
    return 0 if total == want else 1


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--coordinator", required=True,
                    help="coordinator address, host:port (rank 0 binds it)")
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--cpu-collectives", default="gloo",
                    help="jax_cpu_collectives_implementation ('' to skip)")
    return _smoke(ap.parse_args(argv))


if __name__ == "__main__":
    raise SystemExit(main())
