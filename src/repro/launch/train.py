"""End-to-end federated LM training driver (deliverable (b) backbone).

Trains an assigned-architecture (reduced or full) causal LM with FedGKD
across K clients holding non-IID synthetic token streams (per-client Markov
sources).  Two execution paths:

  serial    one client at a time (any device count) — the FL-simulation path
  sharded   clients mapped onto the mesh "data" axis via shard_map: every
            client's local epoch runs concurrently with NO cross-client
            collectives; aggregation is a single weighted psum — the
            jax-native image of the paper's MPI round (DESIGN.md §4)

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --smoke --rounds 5 --clients 4 --algo fedgkd
"""
from __future__ import annotations

import argparse
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.core.distillation import ensemble_average
from repro.core.server import ModelBuffer, weighted_average
from repro.data.synthetic import lm_token_batches
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.optim import sgd
from repro.sharding import shard_map_compat

Params = Any


# ---------------------------------------------------------------------------
# data: per-client non-IID token streams
# ---------------------------------------------------------------------------

def client_batches(cfg, n_clients: int, batches_per_round: int, batch: int,
                   seq: int, seed: int = 0) -> np.ndarray:
    """(K, B_per_round, batch, seq) int32 — each client draws from its own
    Markov source (label-distribution skew analogue for LM data)."""
    out = np.empty((n_clients, batches_per_round, batch, seq), np.int32)
    for k in range(n_clients):
        rng = np.random.default_rng(seed * 1000 + k)
        for b in range(batches_per_round):
            out[k, b] = lm_token_batches(rng, batch, seq, cfg.vocab_size)
    return out


def eval_ppl(params, cfg, tokens: jnp.ndarray) -> float:
    logits, _ = transformer.forward(params, cfg, tokens[:, :-1])
    ce = steps_lib.lm_cross_entropy(logits, tokens[:, 1:])
    return float(jnp.exp(ce))


# ---------------------------------------------------------------------------
# serial FL round
# ---------------------------------------------------------------------------

def make_round_clock(n_clients: int, *, straggler_frac: float,
                     straggler_slowdown: float, seed: int):
    """Optional simulated system-heterogeneity clock for the LM drivers.

    Returns ``None`` (no simulation) or a callable mapping per-round work
    (batches per client) to the SYNCHRONOUS barrier cost — the virtual
    seconds until the slowest client of the round finishes
    (``repro.core.systemsim`` speeds, straggler profile).  The drivers
    attach it as ``sim_seconds`` per round so a straggler tail's cost on
    the round barrier is measurable before real heterogeneous hardware
    exists; the single-host FL loop's ``executor="async"`` path is the
    remedy those numbers motivate.
    """
    if straggler_frac <= 0.0:
        return None
    from repro.core import systemsim
    sim = systemsim.SystemSim(
        n_clients,
        systemsim.SpeedProfile(kind="straggler",
                               straggler_frac=straggler_frac,
                               straggler_slowdown=straggler_slowdown),
        rng=systemsim.derive_rng(seed))
    return lambda work: max(sim.duration(k, work) for k in range(n_clients))


def run_serial(cfg, *, rounds: int, n_clients: int, batches_per_round: int,
               batch: int, seq: int, algo: str = "fedgkd", gamma: float = 0.2,
               buffer_m: int = 3, lr: float = 0.1, seed: int = 0,
               verbose: bool = True, straggler_frac: float = 0.0,
               straggler_slowdown: float = 4.0) -> dict:
    round_clock = make_round_clock(n_clients, straggler_frac=straggler_frac,
                                   straggler_slowdown=straggler_slowdown,
                                   seed=seed)
    opt = sgd(momentum=0.9)
    kd_mode = "teacher" if algo == "fedgkd" else "none"
    step = jax.jit(steps_lib.make_train_step(cfg, opt, kd_mode=kd_mode,
                                             gamma=gamma, lr=lr))
    global_params = transformer.init(jax.random.PRNGKey(seed), cfg)
    buf = ModelBuffer(buffer_m)
    buf.push(global_params)
    eval_toks = jnp.asarray(lm_token_batches(
        np.random.default_rng(9999), 8, seq, cfg.vocab_size))
    history = []
    for t in range(rounds):
        t0 = time.time()
        data = client_batches(cfg, n_clients, batches_per_round, batch, seq,
                              seed=seed + t)
        teacher = ensemble_average(buf.models) if kd_mode == "teacher" else ()
        new_params, weights = [], []
        for k in range(n_clients):
            p = global_params
            o = opt.init(p)
            for b in range(batches_per_round):
                bt = jnp.asarray(data[k, b])
                batch_dict = {"tokens": bt[:, :-1], "labels": bt[:, 1:]}
                p, o, metrics = step(p, teacher, o, batch_dict)
            new_params.append(p)
            weights.append(float(batch * batches_per_round))
        global_params = weighted_average(new_params, weights)
        buf.push(global_params)
        ppl = eval_ppl(global_params, cfg, eval_toks)
        rec = {"round": t + 1, "ppl": ppl, "loss": float(metrics["loss"]),
               "seconds": time.time() - t0}
        if round_clock is not None:
            rec["sim_seconds"] = round_clock(batches_per_round)
        history.append(rec)
        if verbose:
            print(f"[{algo}] round {t+1}/{rounds} ppl={ppl:.2f} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"({history[-1]['seconds']:.1f}s)", flush=True)
    return {"history": history, "params": global_params}


# ---------------------------------------------------------------------------
# shard_map client-parallel FL round
# ---------------------------------------------------------------------------

def make_parallel_round(cfg, mesh: Mesh, *, gamma: float = 0.2,
                        lr: float = 0.1, kd_mode: str = "teacher"):
    """FL round as ONE jitted program: clients sharded over the mesh's
    "clients" axis; local scans have no collectives; aggregation = psum."""
    opt = sgd(momentum=0.9)
    step = steps_lib.make_train_step(cfg, opt, kd_mode=kd_mode, gamma=gamma,
                                     lr=lr)

    def per_client(params, teacher, tokens):
        # tokens: (B_per_round, batch, seq) for THIS client
        opt_state = opt.init(params)

        def body(carry, bt):
            p, o = carry
            batch_dict = {"tokens": bt[:, :-1], "labels": bt[:, 1:]}
            p, o, m = step(p, teacher, o, batch_dict)
            return (p, o), m["loss"]

        (params, _), losses = jax.lax.scan(body, (params, opt_state), tokens)
        return params, jnp.mean(losses)

    def round_fn(global_params, teacher, tokens, weights):
        # leading axis = clients (sharded): run my shard's client, aggregate
        params = jax.tree_util.tree_map(lambda x: x[0], global_params)
        teacher_l = jax.tree_util.tree_map(lambda x: x[0], teacher) \
            if kd_mode == "teacher" else ()
        new_params, loss = per_client(params, teacher_l, tokens[0])
        w = weights[0]
        total = jax.lax.psum(w, "clients")
        agg = jax.tree_util.tree_map(
            lambda p: jax.lax.psum(p * (w / total), "clients").astype(p.dtype),
            new_params)
        loss_mean = jax.lax.pmean(loss, "clients")
        return (jax.tree_util.tree_map(lambda x: x[None], agg),
                loss_mean[None])

    spec_c = P("clients")
    pspec = jax.tree_util.tree_map(lambda _: spec_c, jax.eval_shape(
        lambda: transformer.init(jax.random.PRNGKey(0), cfg)))
    in_specs = (pspec, pspec if kd_mode == "teacher" else P(),
                spec_c, spec_c)
    out_specs = (pspec, spec_c)
    fn = shard_map_compat(round_fn, mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return jax.jit(fn)


def run_sharded(cfg, *, rounds: int, batches_per_round: int, batch: int,
                seq: int, gamma: float = 0.2, buffer_m: int = 3,
                lr: float = 0.1, seed: int = 0, algo: str = "fedgkd",
                verbose: bool = True, straggler_frac: float = 0.0,
                straggler_slowdown: float = 4.0) -> dict:
    """Clients == host devices; one shard_map program per round."""
    n_clients = len(jax.devices())
    round_clock = make_round_clock(n_clients, straggler_frac=straggler_frac,
                                   straggler_slowdown=straggler_slowdown,
                                   seed=seed)
    mesh = jax.make_mesh((n_clients,), ("clients",))
    kd_mode = "teacher" if algo == "fedgkd" else "none"
    round_fn = make_parallel_round(cfg, mesh, gamma=gamma, lr=lr,
                                   kd_mode=kd_mode)
    global_params = transformer.init(jax.random.PRNGKey(seed), cfg)
    buf = ModelBuffer(buffer_m)
    buf.push(global_params)
    eval_toks = jnp.asarray(lm_token_batches(
        np.random.default_rng(9999), 8, seq, cfg.vocab_size))
    bcast = lambda tree: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), tree)
    history = []
    for t in range(rounds):
        t0 = time.time()
        data = jnp.asarray(client_batches(cfg, n_clients, batches_per_round,
                                          batch, seq, seed=seed + t))
        teacher = ensemble_average(buf.models) if kd_mode == "teacher" else ()
        weights = jnp.ones((n_clients,), jnp.float32)
        stacked, loss = round_fn(bcast(global_params),
                                 bcast(teacher) if kd_mode == "teacher" else (),
                                 data, weights)
        global_params = jax.tree_util.tree_map(lambda x: x[0], stacked)
        buf.push(global_params)
        ppl = eval_ppl(global_params, cfg, eval_toks)
        rec = {"round": t + 1, "ppl": ppl, "loss": float(loss[0]),
               "seconds": time.time() - t0}
        if round_clock is not None:
            rec["sim_seconds"] = round_clock(batches_per_round)
        history.append(rec)
        if verbose:
            print(f"[{algo}/sharded] round {t+1}/{rounds} ppl={ppl:.2f} "
                  f"loss={float(loss[0]):.4f}", flush=True)
    return {"history": history, "params": global_params}


def run_fl_task(args) -> int:
    """Single-host FL-loop preset path: ``--fl-task cifar10`` etc.

    Drives ``fl_loop.run_federated`` on a paper task (``model=resnet8`` for
    the CIFAR tasks) under the chosen executor.  ``--executor vmap`` on the
    conv backbones runs the client-batched grouped-conv round body
    (``kernels.grouped_conv``) — the historical "batched-weight convs lower
    poorly under vmap" caveat no longer applies; the route that actually
    ran is printed from the telemetry.
    """
    import dataclasses

    from repro.configs.paper import PAPER_TASKS, scaled
    from repro.core import algorithms as algo_lib
    from repro.core import fl_loop

    task = scaled(PAPER_TASKS[args.fl_task], scale=args.fl_scale,
                  rounds=args.rounds, local_epochs=1)
    if args.clients:
        task = dataclasses.replace(
            task, n_clients=max(task.n_clients, args.clients),
            participation=args.clients / max(task.n_clients, args.clients))
    data = fl_loop.make_federated_data(task, alpha=10.0, seed=0, n_test=256)
    h = fl_loop.run_federated(
        task, algo_lib.make(args.algo, gamma=args.gamma,
                            buffer_m=args.buffer_m),
        data, seed=0, width=args.fl_width, executor=args.executor,
        max_batches_per_client=args.batches_per_round, verbose=True)
    print(f"model={task.model} executor={args.executor} "
          f"round_body={h.telemetry.get('round_body', '-')} "
          f"final_acc={h.final_acc:.4f}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--fl-task", default=None, choices=sorted(
                        ("cifar10", "cifar100", "tiny-imagenet", "toy")),
                    help="run the single-host FL loop on a paper task "
                         "(model=resnet8/resnet50/mlp per task) instead of "
                         "the LM driver; --executor selects the route")
    ap.add_argument("--executor", default="auto",
                    help="FL-task executor: auto/sequential/vmap/shard_map/"
                         "async (vmap on the conv backbones uses the "
                         "client-batched grouped-conv body)")
    ap.add_argument("--fl-scale", type=float, default=0.02,
                    help="FL-task dataset scale (CPU-sized default)")
    ap.add_argument("--fl-width", type=int, default=16,
                    help="resnet8 width for --fl-task")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--algo", choices=("fedavg", "fedgkd"), default="fedgkd")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batches-per-round", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.2)
    ap.add_argument("--buffer-m", type=int, default=3)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--sharded", action="store_true",
                    help="clients-in-parallel via shard_map")
    ap.add_argument("--straggler-frac", type=float, default=0.0,
                    help="simulate a straggler tail: this fraction of "
                         "clients runs --straggler-slowdown x slower and "
                         "each round reports sim_seconds (the synchronous "
                         "barrier cost on the virtual clock)")
    ap.add_argument("--straggler-slowdown", type=float, default=4.0)
    args = ap.parse_args(argv)

    if args.fl_task:
        return run_fl_task(args)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    kw = dict(rounds=args.rounds, batches_per_round=args.batches_per_round,
              batch=args.batch, seq=args.seq, gamma=args.gamma,
              buffer_m=args.buffer_m, lr=args.lr, algo=args.algo,
              straggler_frac=args.straggler_frac,
              straggler_slowdown=args.straggler_slowdown)
    if args.sharded:
        out = run_sharded(cfg, **kw)
    else:
        out = run_serial(cfg, n_clients=args.clients, **kw)
    print("final ppl:", out["history"][-1]["ppl"])
    if args.straggler_frac > 0:
        total = sum(r["sim_seconds"] for r in out["history"])
        print(f"simulated round-barrier time: {total:.1f} virtual s over "
              f"{args.rounds} rounds (straggler tail "
              f"{args.straggler_frac:.0%} at "
              f"{args.straggler_slowdown:g}x slowdown)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
