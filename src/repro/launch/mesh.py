"""Production meshes (TPU v5e pods).

FUNCTIONS, not module constants — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever host devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh_compat((n // model, model), ("data", "model"))


def make_clients_mesh(n: int | None = None) -> jax.sharding.Mesh:
    """1-D ``("clients",)`` mesh for cohort-parallel federated rounds.

    Used by ``repro.core.executor.ShardMapExecutor`` (and the
    ``repro.launch.train --sharded`` driver): the sampled cohort's client
    axis is sharded over it, weights stay replicated.  Defaults to every
    visible device; on a CPU dev box force several host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = len(jax.devices()) if n is None else n
    return make_mesh_compat((n,), ("clients",))


def make_local_clients_mesh(n: int | None = None) -> jax.sharding.Mesh:
    """``("clients",)`` mesh over THIS PROCESS's devices only.

    In a multi-process topology ``jax.devices()`` is the global device
    set; a host that trains just its owned cohort slice (population
    multi-host placement — ``repro.population.placement``) shards that
    slice over ``jax.local_devices()``.  Single-process, this is exactly
    ``make_clients_mesh``.
    """
    import numpy as np

    devs = jax.local_devices()
    n = len(devs) if n is None else n
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("clients",))
