"""Production meshes (TPU v5e pods).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever host devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
