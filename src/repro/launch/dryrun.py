import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run CLI (deliverable (e)).

Lowers + compiles train/prefill/serve steps for every assigned
(architecture × input shape) on the production meshes and records the
roofline inputs.  Examples:

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single \
        --out results/dryrun_single.json
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3-671b \
        --shape train_4k --kd cached_topk       # beyond-paper variant
"""
import argparse
import json
import sys


def main(argv=None) -> int:
    from repro.configs import ALL_ARCHS, SHAPES
    from repro.launch import dryrun_lib

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch × shape)")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--kd", choices=("none", "teacher", "cached_topk"),
                    default="teacher",
                    help="train-step KD mode (teacher = paper-faithful)")
    ap.add_argument("--fsdp", choices=("auto", "on", "off"), default="auto")
    ap.add_argument("--probe", action="store_true",
                    help="exact roofline terms via unrolled depth probes")
    ap.add_argument("--out", default=None, help="append JSON lines here")
    args = ap.parse_args(argv)

    pairs = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]

    results = []
    n_fail = 0
    for multi in meshes:
        for arch, shape in pairs:
            r = dryrun_lib.run_dryrun(arch, shape, multi_pod=multi,
                                      kd_mode=args.kd, fsdp=fsdp,
                                      probe=args.probe)
            print(dryrun_lib.result_line(r), flush=True)
            if r.memory:
                print(f"    memory_analysis: {r.memory}", flush=True)
            results.append(r.to_json())
            if not r.ok and not r.error.startswith("SKIP"):
                n_fail += 1

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "a") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
    print(f"\n{len(results)} runs, {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
