"""Batched serving driver: continuous prefill + decode over a request queue.

A miniature inference runtime for the assigned architectures: requests
arrive with prompts, get packed into a fixed batch, prefilled through the
KV cache, then decoded greedily; finished slots are refilled from the queue
(continuous batching at round granularity).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \
        --requests 8 --batch 4 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer


class ServeLoop:
    def __init__(self, cfg, params, batch: int, max_len: int,
                 cache_dtype=jnp.float32):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self.cache = transformer.init_cache(cfg, batch, max_len, cache_dtype)
        self.decode = jax.jit(
            lambda p, t, c: transformer.decode_step(p, cfg, t, c))

    def run(self, prompts: list[np.ndarray], gen: int) -> dict:
        """Serve all prompts; returns {latency stats, tokens/s, outputs}."""
        queue = list(enumerate(prompts))
        outputs: dict[int, list[int]] = {}
        n_steps = 0
        t0 = time.time()
        while queue:
            wave, queue = queue[: self.batch], queue[self.batch:]
            # fresh cache per wave (simple batch-synchronous serving)
            cache = transformer.init_cache(self.cfg, self.batch, self.max_len,
                                           jnp.float32)
            plen = max(len(p) for _, p in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, (_, p) in enumerate(wave):
                toks[i, plen - len(p):] = p           # left-pad
            toks = jnp.asarray(toks)
            logits = None
            for i in range(plen):                      # prefill via decode
                logits, cache = self.decode(self.params, toks[:, i:i + 1],
                                            cache)
                n_steps += 1
            tok = jnp.argmax(logits[:, -1:], axis=-1)
            gen_toks = [tok]
            for _ in range(gen - 1):
                logits, cache = self.decode(self.params, tok, cache)
                tok = jnp.argmax(logits[:, -1:], axis=-1)
                gen_toks.append(tok)
                n_steps += 1
            out = np.asarray(jnp.concatenate(gen_toks, axis=1))
            for i, (rid, _) in enumerate(wave):
                outputs[rid] = out[i].tolist()
        dt = time.time() - t0
        return {"outputs": outputs, "seconds": dt,
                "decode_steps": n_steps,
                "tok_per_s": n_steps * self.batch / max(dt, 1e-9)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=rng.integers(4, args.prompt_len + 1))
               .astype(np.int32) for _ in range(args.requests)]
    loop = ServeLoop(cfg, params, args.batch,
                     args.prompt_len + args.gen + 1)
    stats = loop.run(prompts, args.gen)
    print(f"served {args.requests} requests in {stats['seconds']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s, batch={args.batch})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
