"""Train / serve step factories shared by the dry-run and the live drivers.

Federated mapping onto pods (DESIGN.md §4): within a round, a pod runs ONE
client's local steps — the "data" axis is within-client batch parallelism,
"model" is tensor parallel.  On the multi-pod mesh the "pod" axis carries
TWO clients training concurrently; ``make_aggregate_step`` is the server's
weighted parameter average (one psum over "pod").

``make_train_step`` builds the FedGKD local objective (Eq. 4):

    L = CE(student(x), y) + aux(MoE) [+ λ·CE_MTP] + (γ/2)·KL(teacher ‖ student)

kd_mode:
    "none"         FedAvg baseline local step (no KD term)
    "teacher"      paper-faithful: full teacher forward each step
    "cached_topk"  beyond-paper: per-batch cached top-K teacher logits
                   (teacher forward amortized out of the step; §Perf)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distillation as D
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import Optimizer, apply_updates, sgd


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_cross_entropy(logits: jax.Array, labels: jax.Array,
                     text_offset: int = 0) -> jax.Array:
    """Next-token CE. logits (B, S_total, V); labels (B, S_text) aligned to
    the last S_text positions (frontend prefix positions carry no loss)."""
    if text_offset:
        logits = logits[:, text_offset:]
    return D.cross_entropy(logits, labels)


def kd_topk_kl(topk_vals: jax.Array, topk_idx: jax.Array,
               student_logits: jax.Array) -> jax.Array:
    """Sparse KD: teacher distribution restricted+renormalized to its top-K.

    topk_vals/idx: (..., K) teacher logits and vocab ids;
    student_logits: (..., V).  Returns per-position KL(p̂_T ‖ p_S)."""
    p_t = jax.nn.softmax(topk_vals.astype(jnp.float32), axis=-1)
    logp_t = jax.nn.log_softmax(topk_vals.astype(jnp.float32), axis=-1)
    lse_s = jax.nn.logsumexp(student_logits.astype(jnp.float32), axis=-1)
    ls_at = jnp.take_along_axis(student_logits.astype(jnp.float32),
                                topk_idx, axis=-1)
    logp_s = ls_at - lse_s[..., None]
    return jnp.sum(p_t * (logp_t - logp_s), axis=-1)


def _forward(params, cfg: ModelConfig, batch: dict):
    kw = {}
    if cfg.enc_layers:
        kw["enc_out"] = transformer.encode(params, cfg, batch["enc_embeddings"])
    elif cfg.frontend:
        kw["prefix_embeddings"] = batch["frontend_embeddings"]
    logits, aux = transformer.forward(params, cfg, batch["tokens"], **kw)
    return logits, aux


def make_loss_fn(cfg: ModelConfig, *, kd_mode: str = "teacher",
                 gamma: float = 0.2, kd_temperature: float = 1.0,
                 mtp_weight: float = 0.3, use_pallas_kd: bool = False):
    """loss(params, teacher_params, batch) -> (loss, metrics)."""
    text_offset = 0
    if cfg.frontend and not cfg.enc_layers:
        from repro.models import frontends
        text_offset = cfg.frontend_seq or frontends.frontend_seq(cfg.frontend)

    def loss_fn(params, teacher_params, batch):
        logits, aux = _forward(params, cfg, batch)
        ce = lm_cross_entropy(logits, batch["labels"], text_offset)
        loss = ce + aux
        metrics = {"ce": ce, "aux": aux}

        if cfg.mtp_depth:
            h, _ = transformer.hidden_states(
                params, cfg, batch["tokens"],
                batch.get("frontend_embeddings") if cfg.frontend and not cfg.enc_layers else None,
            )
            if text_offset:
                h = h[:, text_offset:]
            mtp = transformer.mtp_logits(params, cfg, h, batch["labels"])
            mtp_targets = jnp.concatenate(
                [batch["labels"][:, 1:], -jnp.ones_like(batch["labels"][:, :1])], 1)
            mtp_ce = D.cross_entropy(mtp, mtp_targets)
            loss = loss + mtp_weight * mtp_ce
            metrics["mtp_ce"] = mtp_ce

        if kd_mode == "teacher":
            t_logits, _ = _forward(jax.lax.stop_gradient(teacher_params),
                                   cfg, batch)
            t_logits = jax.lax.stop_gradient(t_logits)
            if use_pallas_kd:
                from repro.kernels.kd_kl import kd_kl_loss
                kl = kd_kl_loss(t_logits.reshape(-1, t_logits.shape[-1]),
                                logits.reshape(-1, logits.shape[-1]),
                                temperature=kd_temperature)
            else:
                kl = D.kl_divergence(t_logits, logits, kd_temperature)
            kd = 0.5 * gamma * jnp.mean(kl)
            loss = loss + kd
            metrics["kd"] = kd
        elif kd_mode == "cached_topk":
            if text_offset:
                s_logits = logits[:, text_offset:]
            else:
                s_logits = logits
            kl = kd_topk_kl(batch["teacher_topk_vals"],
                            batch["teacher_topk_idx"], s_logits)
            kd = 0.5 * gamma * jnp.mean(kl)
            loss = loss + kd
            metrics["kd"] = kd
        return loss, metrics

    return loss_fn


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt: Optional[Optimizer] = None, *,
                    kd_mode: str = "teacher", gamma: float = 0.2,
                    kd_temperature: float = 1.0, lr: float = 0.05,
                    mtp_weight: float = 0.3, use_pallas_kd: bool = False):
    """Returns step(params, teacher_params, opt_state, batch) ->
    (params, opt_state, metrics).  ``teacher_params=()`` when kd_mode!="teacher"."""
    opt = opt or sgd(momentum=0.9, weight_decay=1e-5)
    loss_fn = make_loss_fn(cfg, kd_mode=kd_mode, gamma=gamma,
                           kd_temperature=kd_temperature,
                           mtp_weight=mtp_weight, use_pallas_kd=use_pallas_kd)

    def step(params, teacher_params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, teacher_params, batch)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_serve_step(cfg: ModelConfig, *, sample: bool = False):
    """serve_step(params, cache, tokens [, enc_out]) -> (logits, cache)."""

    def step(params, cache, tokens, enc_out=None):
        logits, cache = transformer.decode_step(params, cfg, tokens, cache,
                                                enc_out=enc_out)
        return logits, cache

    return step


def make_prefill_step(cfg: ModelConfig, *, last_only: bool = False):
    """prefill(params, batch) -> logits — inference forward, no grads.

    ``last_only`` emits only the final position's logits (what a serving
    stack actually needs before decode) — avoids writing the full
    (B, S, V) tensor, a §Perf memory/collective win.
    """

    def step(params, batch):
        if last_only:
            h, _ = transformer.hidden_states(
                params, cfg, batch["tokens"],
                batch.get("frontend_embeddings"),
                transformer.encode(params, cfg, batch["enc_embeddings"])
                if cfg.enc_layers else None)
            return transformer.logits_from_hidden(params, cfg, h[:, -1:])
        logits, _ = _forward(params, cfg, batch)
        return logits

    return step


def make_aggregate_step(axis: str = "pod"):
    """Server aggregation: weighted mean of client params over ``axis``
    (Alg. 1 line 14 as one psum).  Run under shard_map with client-sharded
    param replicas."""

    def aggregate(params, weight):
        total = jax.lax.psum(weight, axis)

        def avg(p):
            return jax.lax.psum(p * (weight / total), axis).astype(p.dtype)

        return jax.tree_util.tree_map(avg, params)

    return aggregate
