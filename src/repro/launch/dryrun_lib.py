"""Dry-run machinery: lower + compile every (arch × shape × mesh) and emit
memory/cost/collective statistics.  Import ONLY after jax device init is
configured (launch/dryrun.py sets XLA_FLAGS first).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.configs import SHAPES, get_config, input_specs
from repro.launch import hlo_stats, roofline, steps
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import sgd

# long_500k applicability (DESIGN.md §Arch-applicability): sub-quadratic
# backbones only; phi4 runs it via the sliding-window long_variant.
LONG_CTX_ARCHS = {"mamba2-2.7b", "zamba2-1.2b", "mixtral-8x7b"}
LONG_CTX_SWA_OVERRIDE = {"phi4-mini-3.8b": 4096}

# FSDP (shard params over "data" too) for archs whose TP-only per-chip
# weights exceed a v5e budget.
FSDP_BYTES_THRESHOLD = 2 << 30


@dataclasses.dataclass
class DryRunResult:
    arch: str
    shape: str
    mesh: str
    kd_mode: str
    ok: bool
    seconds: float
    error: str = ""
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_summary: str = ""
    memory: dict = dataclasses.field(default_factory=dict)
    report: Optional[dict] = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def resolve_config(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch in LONG_CTX_SWA_OVERRIDE:
        cfg = cfg.replace(attn_window=LONG_CTX_SWA_OVERRIDE[arch])
    return cfg


def shape_supported(arch: str, shape_name: str) -> tuple[bool, str]:
    if shape_name != "long_500k":
        return True, ""
    if arch in LONG_CTX_ARCHS or arch in LONG_CTX_SWA_OVERRIDE:
        return True, ""
    return False, ("full-attention arch: 524k-token KV decode is quadratic-"
                   "class; skipped per DESIGN.md §Arch-applicability")


def needs_fsdp(cfg: ModelConfig, mesh) -> bool:
    model_par = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    per_chip = cfg.param_count() * 2 / model_par   # bf16
    return per_chip > FSDP_BYTES_THRESHOLD


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_lowering(arch: str, shape_name: str, mesh, *, kd_mode: str = "teacher",
                   fsdp: Optional[bool] = None, donate: bool = True,
                   extra_cfg: Optional[dict] = None,
                   prefill_last_only: bool = False):
    """Construct the jitted step for (arch, shape) and lower it on ``mesh``.

    Lowering happens under ``use_mesh`` so that bare-PartitionSpec
    ``with_sharding_constraint`` calls inside the model (MoE dispatch
    constraints, §Perf) resolve against the production mesh.
    """
    with mesh:
        return _build_lowering(arch, shape_name, mesh, kd_mode=kd_mode,
                               fsdp=fsdp, donate=donate, extra_cfg=extra_cfg,
                               prefill_last_only=prefill_last_only)


def _build_lowering(arch: str, shape_name: str, mesh, *, kd_mode: str,
                    fsdp: Optional[bool], donate: bool,
                    extra_cfg: Optional[dict], prefill_last_only: bool = False):
    cfg = resolve_config(arch, shape_name)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    shape = SHAPES[shape_name]
    fsdp = needs_fsdp(cfg, mesh) if fsdp is None else fsdp

    param_shapes = jax.eval_shape(
        lambda: transformer.init(jax.random.PRNGKey(0), cfg))
    pspecs = sh.specs_with_mesh(param_shapes, cfg, mesh, fsdp=fsdp)
    psharding = _named(mesh, pspecs)

    batch = input_specs(cfg, shape_name)

    if shape.mode == "decode":
        step = steps.make_serve_step(cfg)
        cache_shapes = batch["cache"]
        cspecs = sh.fit_specs(sh.cache_specs(cache_shapes, mesh),
                              cache_shapes, mesh)
        csharding = _named(mesh, cspecs)
        dp = sh.data_axes(mesh)
        dp = dp[0] if len(dp) == 1 else dp
        tok_spec = sh.fit_specs(P(dp, None), batch["tokens"], mesh)
        tok_sharding = NamedSharding(mesh, tok_spec)
        args = (param_shapes, cache_shapes, batch["tokens"])
        in_sh = (psharding, csharding, tok_sharding)
        if "enc_out" in batch:
            args += (batch["enc_out"],)
            enc_spec = sh.fit_specs(P(dp, None, None), batch["enc_out"], mesh)
            in_sh += (NamedSharding(mesh, enc_spec),)
        # out_shardings left to XLA: pinning the cache output replicated on
        # "model" forces a full-cache all-gather each step (measured: 68 GB
        # for phi4/decode_32k) — the propagated sharding keeps the cache
        # partitioned exactly as the attention computation consumed it.
        jitted = jax.jit(step, in_shardings=in_sh,
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(*args)
        return cfg, lowered, {"mode": "decode", "fsdp": fsdp}

    if shape.mode == "prefill":
        step = steps.make_prefill_step(cfg, last_only=prefill_last_only)
        bspecs = sh.fit_specs(sh.batch_specs(batch, mesh), batch, mesh)
        bsharding = _named(mesh, bspecs)
        dp = sh.data_axes(mesh)
        dp = dp[0] if len(dp) == 1 else dp
        out_shape = jax.eval_shape(step, param_shapes, batch)
        out_spec = sh.fit_specs(P(dp, None, "model"), out_shape, mesh)
        jitted = jax.jit(step, in_shardings=(psharding, bsharding),
                         out_shardings=NamedSharding(mesh, out_spec))
        lowered = jitted.lower(param_shapes, batch)
        return cfg, lowered, {"mode": "prefill", "fsdp": fsdp}

    # train
    opt = sgd(momentum=0.9, weight_decay=1e-5)
    step = steps.make_train_step(cfg, opt, kd_mode=kd_mode)
    opt_shapes = jax.eval_shape(opt.init, param_shapes)
    ospecs = _opt_specs(opt_shapes, pspecs)
    osharding = _named(mesh, ospecs)
    if kd_mode == "teacher":
        teacher_shapes, tsharding = param_shapes, psharding
    else:
        teacher_shapes, tsharding = (), ()
    if kd_mode == "cached_topk":
        k = 64
        b, s = batch["labels"].shape
        batch = dict(batch)
        batch["teacher_topk_vals"] = jax.ShapeDtypeStruct((b, s, k), jnp.bfloat16)
        batch["teacher_topk_idx"] = jax.ShapeDtypeStruct((b, s, k), jnp.int32)
    bspecs = sh.fit_specs(sh.batch_specs(batch, mesh), batch, mesh)
    bsharding = _named(mesh, bspecs)
    metric_sh = NamedSharding(mesh, P())
    jitted = jax.jit(
        step,
        in_shardings=(psharding, tsharding, osharding, bsharding),
        out_shardings=(psharding, osharding,
                       jax.tree_util.tree_map(lambda _: metric_sh,
                                              _metric_template(cfg, kd_mode))),
        donate_argnums=(0, 2) if donate else ())
    lowered = jitted.lower(param_shapes, teacher_shapes, opt_shapes, batch)
    return cfg, lowered, {"mode": "train", "fsdp": fsdp}


def _metric_template(cfg, kd_mode):
    m = {"ce": 0.0, "aux": 0.0, "loss": 0.0}
    if cfg.mtp_depth:
        m["mtp_ce"] = 0.0
    if kd_mode in ("teacher", "cached_topk"):
        m["kd"] = 0.0
    return m


def _opt_specs(opt_shapes, pspecs):
    """Optimizer state shards like the params (SGD momentum mirrors the param
    tree exactly; empty state -> empty specs)."""
    flat_o = jax.tree_util.tree_leaves(opt_shapes)
    flat_p = jax.tree_util.tree_leaves(pspecs,
                                       is_leaf=lambda x: isinstance(x, P))
    if len(flat_o) == len(flat_p):
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(opt_shapes), flat_p)
    return jax.tree_util.tree_map(lambda _: P(), opt_shapes)


def _probe_depths(cfg: ModelConfig) -> tuple[int, int]:
    """Two depths for the affine cost extrapolation.  Constraints: both >
    first_k_dense (so the MoE segment exists) and multiples of the hybrid
    shared-attn period (so shared-block count scales linearly)."""
    if cfg.shared_attn_period:
        p = cfg.shared_attn_period
        return p, 2 * p
    if cfg.first_k_dense:
        return cfg.first_k_dense + 2, cfg.first_k_dense + 4
    return 2, 4


def _probe_overrides(cfg: ModelConfig, n_layers: int) -> dict:
    ov: dict = {"n_layers": n_layers, "scan_layers": False}
    if cfg.moe is not None:
        ov["moe"] = cfg.moe._replace(batched_groups=True)
    return ov


def probe_costs(arch: str, shape_name: str, mesh, *, kd_mode: str = "teacher",
                fsdp: Optional[bool] = None,
                extra_cfg: Optional[dict] = None,
                prefill_last_only: bool = False) -> dict:
    """Exact roofline inputs via two UNROLLED reduced-depth lowerings.

    XLA's cost_analysis counts a while-loop body once, so the scan-over-
    layers program under-reports FLOPs/bytes/collectives by ~n_layers ×.
    Total cost is affine in depth L (fixed first_k_dense / shared period),
    so two unrolled probes at depths (a, b) give the exact per-layer slope;
    extrapolating to the full L recovers the true per-device totals.
    """
    cfg = resolve_config(arch, shape_name)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    a, b = _probe_depths(cfg)

    def measure(n_layers: int):
        ov = _probe_overrides(cfg, n_layers)
        if extra_cfg:
            ov = {**extra_cfg, **ov}
            if "moe" in extra_cfg and cfg.moe is not None:
                ov["moe"] = extra_cfg["moe"]._replace(batched_groups=True)
        _, lowered, _ = build_lowering(arch, shape_name, mesh,
                                       kd_mode=kd_mode, fsdp=fsdp,
                                       extra_cfg=ov,
                                       prefill_last_only=prefill_last_only)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        cstats = hlo_stats.collective_stats(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)),
                float(cstats.total_bytes))

    fa = measure(a)
    fb = measure(b)
    L = cfg.n_layers
    out = {}
    for key, va, vb in zip(("flops", "bytes", "collective_bytes"), fa, fb):
        slope = (vb - va) / (b - a)
        base = va - slope * a
        out[key] = base + slope * L
    out["probe_depths"] = (a, b)
    return out


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               kd_mode: str = "teacher", fsdp: Optional[bool] = None,
               extra_cfg: Optional[dict] = None, probe: bool = False,
               prefill_last_only: bool = False,
               compute_roofline: bool = True) -> DryRunResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = shape_supported(arch, shape_name)
    if not ok:
        return DryRunResult(arch, shape_name, mesh_name, kd_mode, False, 0.0,
                            error="SKIP: " + why)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        cfg, lowered, info = build_lowering(
            arch, shape_name, mesh, kd_mode=kd_mode, fsdp=fsdp,
            extra_cfg=extra_cfg, prefill_last_only=prefill_last_only)
        compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        return DryRunResult(arch, shape_name, mesh_name, kd_mode, False,
                            time.time() - t0,
                            error=f"{type(e).__name__}: {e}"[:2000])

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    cstats = hlo_stats.collective_stats(hlo)
    coll_bytes = float(cstats.total_bytes)
    if probe:
        try:
            pc = probe_costs(arch, shape_name, mesh, kd_mode=kd_mode,
                             fsdp=fsdp, extra_cfg=extra_cfg,
                             prefill_last_only=prefill_last_only)
            flops, bytes_acc = pc["flops"], pc["bytes"]
            coll_bytes = pc["collective_bytes"]
        except Exception as e:  # noqa: BLE001 — keep the uncorrected numbers
            print(f"    probe failed ({type(e).__name__}: {e}); "
                  "using scan-body costs", flush=True)
    mem = compiled.memory_analysis()
    memd = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            memd[k] = int(v)

    shape = SHAPES[shape_name]
    if shape.mode == "train":
        n_tokens = shape.global_batch * shape.seq_len
        mf = roofline.model_flops(cfg, n_tokens, "train",
                                  with_teacher=(kd_mode == "teacher"),
                                  mtp=bool(cfg.mtp_depth))
    elif shape.mode == "prefill":
        mf = roofline.model_flops(cfg, shape.global_batch * shape.seq_len,
                                  "prefill")
    else:
        mf = roofline.model_flops(cfg, shape.global_batch * 1, "decode")

    rep = roofline.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_acc,
        collective_bytes=coll_bytes, model_flops=mf)

    return DryRunResult(
        arch, shape_name, mesh_name, kd_mode, True, time.time() - t0,
        flops=flops, bytes_accessed=bytes_acc,
        collective_bytes=coll_bytes,
        collective_summary=cstats.summary(), memory=memd,
        report=rep.row() if compute_roofline else None)


def result_line(r: DryRunResult) -> str:
    if not r.ok:
        return f"[{r.mesh}] {r.arch} × {r.shape} ({r.kd_mode}): {r.error}"
    rep = r.report or {}
    return (f"[{r.mesh}] {r.arch} × {r.shape} ({r.kd_mode}): OK {r.seconds:.1f}s "
            f"flops/dev={r.flops:.3e} bytes/dev={r.bytes_accessed:.3e} "
            f"coll/dev={r.collective_bytes:.3e} dominant={rep.get('dominant','-')} "
            f"[{r.collective_summary}]")
