"""Parse compiled (post-SPMD) HLO text for roofline inputs.

``collective_bytes`` sums the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction in
the per-device program (SPMD HLO is already per-device, so the collective
term is bytes / link_bw without a further chip division).

The HLO text grammar we rely on:  ``%name = <shape> opcode(%op1, %op2, ...)``
with shapes like ``bf16[2,4096,512]{2,1,0}`` or tuples
``(f32[8,128], f32[8,128])``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9\[\],{}\s/_#*]+\)?)\s+"
    r"([\w\-]+)(?:\.\d+)?\(", re.ASCII)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}: n={self.count_by_kind[k]} bytes={v:,}"
                 for k, v in sorted(self.bytes_by_kind.items())]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes per collective kind over the module text."""
    # first pass: map instruction name -> result shape
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2).strip()

    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, opcode = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-start") or \
                    opcode.startswith(c):
                kind = c
                break
        if kind is None or opcode.endswith("-done"):
            continue
        # operand names inside (...) — first level args
        args = line[line.index("(") + 1:]
        ops = re.findall(r"%([\w.\-]+)", args)
        total = 0
        for op in ops:
            if op in shapes:
                total += shape_bytes(shapes[op])
        if total == 0:
            # fallback: use the result shape
            total = shape_bytes(result_shape)
        bytes_by[kind] += total
        count_by[kind] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def op_histogram(hlo_text: str, top: int = 20) -> list[tuple[str, int]]:
    """Opcode frequency — handy for spotting remat-duplicated fusions."""
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            counts[m.group(3)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
