"""The warm host-RAM tier + per-client algorithm-state tiers.

``PopulationStore`` sits between a cold ``ClientSource`` (disk shards or a
seeded generator — see ``repro.population.sources``) and the hot
device-resident ``ClientSlabStore`` (``repro.data.pipeline``):

    cold   the source: O(population) capacity, O(1) host memory
    warm   an LRU of materialized ``ClientData`` capped at ``warm_cap``
           entries — the bound on peak host memory
    hot    the executor's device slab store; the population tier attaches
           to it so a client dropped from warm is also ``drop()``-ed from
           the device (tiers stay coherent top-down) and hot LRU evictions
           feed back into the population counters

Pinning: the async loop keeps a fleet of in-flight clients whose slabs and
states must survive however many waves dispatch before their completions
aggregate — ``pin(cids)`` exempts them from warm, hot AND state-tier
eviction until ``unpin``.  With more pinned clients than the cap the tier
temporarily exceeds it (correctness over the bound; ``peak_warm`` records
the excursion).

``ClientStateStore`` gives the per-client algorithm state dict the same
treatment.  Two regimes, chosen from the algorithm class:

  * STATELESS (``update_client_state`` not overridden — fedavg, fedprox,
    the KD family): states never change after init, so the store holds
    NOTHING and re-inits on every read from the captured initial global
    params — exactly what the eager O(population) dict held;
  * STATEFUL (moon-style ``prev``-model states): a warm LRU capped at
    ``warm_cap`` with evicted states spilled to per-client ``.npz`` files
    (``repro.checkpoint.io``) and reloaded on the client's next sample —
    write-back, never loss.
"""
from __future__ import annotations

import collections
import os
import tempfile
from typing import Any, Callable, Iterable, Optional

from repro.data.pipeline import ClientData
from repro.population.sources import ClientSource


def _evict_lru(od: "collections.OrderedDict", pinned: set):
    """Pop the least-recently-used non-pinned entry (None if all pinned)."""
    for key in od:
        if key not in pinned:
            return key, od.pop(key)
    return None


class PopulationStore:
    """Cold→warm client materialization with a bounded working set."""

    def __init__(self, source: ClientSource,
                 warm_cap: Optional[int] = None):
        self.source = source
        self.warm: "collections.OrderedDict[int, ClientData]" = \
            collections.OrderedDict()
        self.warm_cap = warm_cap
        self.pinned: set[int] = set()
        self.hot = None                 # attached ClientSlabStore (or None)
        self.cold_loads = 0
        self.warm_hits = 0
        self.warm_evictions = 0
        self.hot_evictions = 0          # fed back by the slab store
        self.peak_warm = 0

    @property
    def n_clients(self) -> int:
        return self.source.n_clients

    def attach_hot(self, slab_store) -> None:
        """Couple the device tier: warm evictions drop the client's slab,
        slab-store LRU evictions count into this store's telemetry, and
        the pinned set is shared by reference.  Pins made on the slab
        store BEFORE attach merge into the shared set (never dropped),
        and a pre-existing ``on_evict`` callback is chained, not
        clobbered."""
        self.hot = slab_store
        self.pinned.update(slab_store.pinned)
        slab_store.pinned = self.pinned
        prior = slab_store.on_evict

        def on_evict(cid, entry):
            self.hot_evictions += 1
            if prior is not None:
                prior(cid, entry)

        slab_store.on_evict = on_evict

    def get(self, cid: int) -> ClientData:
        cid = int(cid)
        data = self.warm.get(cid)
        if data is not None:
            self.warm.move_to_end(cid)
            self.warm_hits += 1
            return data
        data = self.source.client(cid)
        self.cold_loads += 1
        self.warm[cid] = data
        while self.warm_cap is not None and len(self.warm) > self.warm_cap:
            victim = _evict_lru(self.warm, self.pinned)
            if victim is None:          # everything pinned: exceed the cap
                break
            self.warm_evictions += 1
            if self.hot is not None:    # keep tiers coherent top-down
                self.hot.drop(victim[0])
        # high-water AFTER eviction: peak_warm > warm_cap if and only if a
        # pinned excursion forced it, which is what tests bound against
        self.peak_warm = max(self.peak_warm, len(self.warm))
        return data

    def client_n(self, cid: int) -> int:
        cid = int(cid)
        data = self.warm.get(cid)
        if data is not None:
            # a size read is a use: refresh recency and count the hit,
            # exactly like get(), so eviction order and telemetry agree
            self.warm.move_to_end(cid)
            self.warm_hits += 1
            return data.n
        return self.source.client_n(cid)

    def pin(self, cids: Iterable[int]) -> None:
        self.pinned.update(int(c) for c in cids)

    def unpin(self, cids: Iterable[int]) -> None:
        self.pinned.difference_update(int(c) for c in cids)

    def stats(self) -> dict:
        return {"warm_resident": len(self.warm), "warm_cap": self.warm_cap,
                "warm_hits": self.warm_hits, "cold_loads": self.cold_loads,
                "warm_evictions": self.warm_evictions,
                "hot_evictions": self.hot_evictions,
                "peak_warm": self.peak_warm, "pinned": len(self.pinned)}


class ClientStateStore:
    """Per-client algorithm state with the same cold/warm discipline.

    Mapping-shaped (``states[cid]`` / ``states[cid] = new``) so the FL loop
    reads and writes it exactly like the historical eager dict.
    """

    def __init__(self, init_fn: Callable[[int], Any], *, mutable: bool,
                 warm_cap: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 pinned: Optional[set] = None):
        self.init_fn = init_fn
        self.mutable = mutable
        self.warm: "collections.OrderedDict[int, Any]" = \
            collections.OrderedDict()
        self.warm_cap = warm_cap
        self.spill_dir = spill_dir
        self.pinned = pinned if pinned is not None else set()
        self.spilled: set[int] = set()
        self.state_inits = 0
        self.state_hits = 0
        self.state_spills = 0
        self.state_loads = 0
        self.state_corrupt_reinits = 0
        self.peak_warm = 0

    def _spill_path(self, cid: int) -> str:
        if self.spill_dir is None:
            self.spill_dir = tempfile.mkdtemp(prefix="repro_client_states_")
        return os.path.join(self.spill_dir, f"state_{cid:09d}.npz")

    def __getitem__(self, cid: int) -> Any:
        cid = int(cid)
        if not self.mutable:
            self.state_inits += 1
            return self.init_fn(cid)
        if cid in self.warm:
            self.warm.move_to_end(cid)
            self.state_hits += 1
            return self.warm[cid]
        if cid in self.spilled:
            from repro.checkpoint.io import CORRUPT_ERRORS, load_pytree
            try:
                state = load_pytree(self._spill_path(cid),
                                    like=self.init_fn(cid))
                self.state_loads += 1
            except CORRUPT_ERRORS as e:
                # a torn/garbage spill file (crash mid-save, disk fault)
                # must not kill the run: the client restarts from its
                # initial state — the same semantics as never having been
                # sampled — and the event is counted + logged
                import logging
                logging.getLogger("repro.population").warning(
                    "corrupt state spill for client %d (%s: %s); "
                    "re-initializing", cid, type(e).__name__, e)
                self.spilled.discard(cid)
                state = self.init_fn(cid)
                self.state_corrupt_reinits += 1
                self.state_inits += 1
        else:
            state = self.init_fn(cid)
            self.state_inits += 1
        self._put(cid, state)
        return state

    def __setitem__(self, cid: int, state: Any) -> None:
        if not self.mutable:
            return                      # states are init-constant: nothing
        self._put(int(cid), state)      # to write back, ever

    def _put(self, cid: int, state: Any) -> None:
        self.warm[cid] = state
        self.warm.move_to_end(cid)
        while self.warm_cap is not None and len(self.warm) > self.warm_cap:
            victim = _evict_lru(self.warm, self.pinned)
            if victim is None:
                break
            vcid, vstate = victim
            from repro.checkpoint.io import save_pytree
            save_pytree(self._spill_path(vcid), vstate)
            self.spilled.add(vcid)
            self.state_spills += 1
        self.peak_warm = max(self.peak_warm, len(self.warm))

    def snapshot(self) -> dict:
        """Checkpoint payload: warm states by VALUE, the spill tier by
        REFERENCE (the set of spilled ids + the spill directory).  Resume
        re-warms lazily — a restored store starts with the same warm
        entries and reloads spilled states from disk on first touch.
        Stateless algorithms snapshot nothing but the marker (their
        states re-derive from ``init_fn``)."""
        snap: dict = {"kind": "state_store", "mutable": self.mutable}
        if self.mutable:
            import jax
            import numpy as np

            def _copy_leaf(leaf):
                # np leaves are mutable: copy them; jax arrays are
                # immutable so the reference IS a value
                if isinstance(leaf, np.ndarray):
                    return np.array(leaf, copy=True)
                return leaf

            snap["warm_cids"] = [int(c) for c in self.warm]
            # tree_map rebuilds the containers too, so a state dict
            # mutated after snapshot cannot tear the checkpoint payload
            snap["warm_states"] = [jax.tree_util.tree_map(_copy_leaf, s)
                                   for s in self.warm.values()]
            snap["spilled"] = sorted(int(c) for c in self.spilled)
            snap["spill_dir"] = self.spill_dir
        return snap

    def restore(self, snap: dict) -> None:
        if bool(snap.get("mutable")) != self.mutable:
            raise ValueError(
                "checkpointed state store mutability does not match this "
                "run's algorithm — resume with the algo it was written "
                "under")
        if not self.mutable:
            return
        self.warm = collections.OrderedDict(
            zip([int(c) for c in snap["warm_cids"]], snap["warm_states"]))
        self.spilled = set(int(c) for c in snap["spilled"])
        spill_dir = snap.get("spill_dir")
        if self.spilled and (spill_dir is None
                             or not os.path.isdir(spill_dir)):
            raise ValueError(
                f"checkpoint references spilled client states under "
                f"{spill_dir!r} but that directory is gone — pass "
                f"state_dir= a durable path to make spills survive "
                f"restarts")
        if spill_dir is not None:
            self.spill_dir = spill_dir

    def stats(self) -> dict:
        return {"state_mutable": self.mutable,
                "state_warm": len(self.warm), "state_spilled": len(self.spilled),
                "state_inits": self.state_inits, "state_hits": self.state_hits,
                "state_spills": self.state_spills,
                "state_loads": self.state_loads,
                "state_corrupt_reinits": self.state_corrupt_reinits,
                "state_peak_warm": self.peak_warm}
