"""Hierarchical O(cohort) sampling over a sharded client population.

The FL loop samples a K-client cohort uniformly WITHOUT replacement each
round.  The flat implementation (``rng.choice(n_clients, K, replace=False)``)
is O(population) per round — numpy builds a permutation-sized workspace —
and, worse, forces the caller to hold an O(population) id array for the
async loop's idle-set refills.  ``HierarchicalSampler`` does the same draw
in two stages over the population's contiguous shards:

  1. shard COUNTS from one multivariate-hypergeometric draw, sized by each
     shard's available-client count — the "size-weighted" stage that keeps
     the marginal exactly uniform-without-replacement over clients;
  2. within each selected shard, offsets uniformly without replacement.

Cost is O(n_shards + cohort) per draw, independent of the population size
(shards are population/shard_size, typically a few hundred at 1M clients);
in the cross-device regime (cohort ≪ population) a rejection fast path
collapses the two stages into one vectorized O(cohort) draw with no
shard-stage cost at all — same distribution, see ``sample``.

Degenerate equivalence (the regression suites pin this down): with
``n_shards == 1`` the two-stage draw collapses to the EXACT flat calls the
loop historically made — ``rng.choice(n, K, replace=False)`` for a fresh
cohort and ``rng.choice(n - |excluded|, K, replace=False)`` mapped through
the sorted idle ids for an async refill — consuming the generator
identically, so a seed reproduces the historical cohort sequence bit for
bit.

Exclusion (the async loop's in-flight clients) is handled by shrinking each
shard's available count and drawing POSITIONS among the survivors, then
shifting positions past the sorted excluded ids back to client ids — an
order-statistics map, O(|excluded| · cohort) with |excluded| ≤ cohort.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np


def shift_positions(pos: np.ndarray, excluded_sorted: np.ndarray) -> np.ndarray:
    """Map positions among the non-excluded ids to the ids themselves.

    ``pos[i] = p`` means "the p-th smallest id not in ``excluded_sorted``";
    the return value is that id.  Equivalent to
    ``np.setdiff1d(np.arange(n), excluded_sorted)[pos]`` without ever
    building the O(n) survivor array.
    """
    out = np.asarray(pos, np.int64).copy()
    for v in excluded_sorted:            # ascending: each shift is final
        out[out >= v] += 1
    return out


class HierarchicalSampler:
    """Uniform-without-replacement cohort sampling in O(shards + cohort).

    ``shard_sizes[s]`` is the number of clients in shard ``s``; shards are
    contiguous id ranges (shard ``s`` owns ids
    ``[starts[s], starts[s] + shard_sizes[s])``).
    """

    def __init__(self, shard_sizes: Iterable[int]):
        self.shard_sizes = np.asarray(list(shard_sizes), np.int64)
        if len(self.shard_sizes) == 0 or (self.shard_sizes <= 0).any():
            raise ValueError(
                f"shard_sizes must be non-empty and positive, got "
                f"{self.shard_sizes!r}")
        self.starts = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(self.shard_sizes)])
        self.n_clients = int(self.starts[-1])
        self.n_shards = len(self.shard_sizes)

    def shard_of(self, cid: int) -> int:
        return int(np.searchsorted(self.starts, cid, side="right") - 1)

    def sample(self, rng: np.random.Generator, k: int,
               exclude: Optional[Iterable[int]] = None) -> np.ndarray:
        """Draw ``k`` distinct client ids uniformly at random, never one in
        ``exclude``.  One shard degenerates to the flat historical calls
        (see the module docstring); more shards do the two-stage draw."""
        exc = (np.unique(np.fromiter(exclude, np.int64))
               if exclude else np.empty(0, np.int64))
        avail_total = self.n_clients - len(exc)
        if k > avail_total:
            raise ValueError(f"cannot sample {k} clients from "
                             f"{avail_total} available")
        if self.n_shards == 1:
            if len(exc) == 0:
                return rng.choice(self.n_clients, size=k, replace=False)
            pos = rng.choice(avail_total, size=k, replace=False)
            return shift_positions(pos, exc)

        # Cross-device regime fast path (cohort + excluded ≪ population):
        # the size-weighted shard stage composed with uniform within-shard
        # offsets IS the uniform k-subset of [0, n) — so draw global ids
        # directly by vectorized rejection: sample every position iid
        # uniform, then redraw excluded hits and later-index duplicates
        # until none remain.  Each position only ever redraws against the
        # exclusion set and earlier positions' final values — sequential
        # sampling without replacement, exactly uniform over survivors —
        # and with (k + |exc|) at most n/64 a draw resolves in O(1)
        # expected rounds.  This skips the O(n_shards) hypergeometric
        # stage entirely; the two-stage draw below remains for dense
        # cohorts where collisions would thrash.
        if (k + len(exc)) * 64 <= self.n_clients:
            out = rng.integers(0, self.n_clients, size=k)
            while True:
                _, first = np.unique(out, return_index=True)
                bad = np.ones(k, bool)
                bad[first] = False
                if len(exc):
                    bad |= np.isin(out, exc)
                if not bad.any():
                    return out
                out[bad] = rng.integers(0, self.n_clients,
                                        size=int(bad.sum()))

        # per-shard available counts (excluded ids bucketed by shard)
        avail = self.shard_sizes.copy()
        exc_shards = np.empty(0, np.int64)
        if len(exc):
            shard_of_exc = np.searchsorted(self.starts, exc,
                                           side="right") - 1
            np.subtract.at(avail, shard_of_exc, 1)
            exc_shards = np.unique(shard_of_exc)
        counts = rng.multivariate_hypergeometric(avail, k)
        sel = np.nonzero(counts)[0]
        with_exc = np.isin(sel, exc_shards)
        out = []
        clean = sel[~with_exc]
        if len(clean):
            # Shards untouched by exclusion (at a K=64 cohort over hundreds
            # of shards: nearly all of them) draw their offsets in ONE
            # vectorized pass: sample every offset iid uniform, then redraw
            # later-index intra-shard duplicates until none remain.  Each
            # position only ever redraws against earlier positions' final
            # values, so the result is exactly sequential sampling without
            # replacement — uniform over distinct offset sets — while a
            # typical draw resolves in zero redraw rounds (collision odds
            # ~ cohort / shard_size per pair).  This replaces a Python loop
            # of per-shard ``rng.choice`` calls whose dispatch overhead
            # dominated the whole draw (~10x the hypergeometric stage).
            sizes_rep = np.repeat(avail[clean], counts[clean])
            shard_rep = np.repeat(clean, counts[clean])
            offs = rng.integers(0, sizes_rep)
            key_base = int(self.shard_sizes.max()) + 1
            while True:
                _, first = np.unique(shard_rep * key_base + offs,
                                     return_index=True)
                if len(first) == len(offs):
                    break
                dup = np.ones(len(offs), bool)
                dup[first] = False
                offs[dup] = rng.integers(0, sizes_rep[dup])
            out.append(self.starts[shard_rep] + offs)
        for s in sel[with_exc]:
            c = int(counts[s])
            lo, size = int(self.starts[s]), int(avail[s])
            pos = rng.choice(size, size=c, replace=False)
            exc_here = exc[(exc >= lo)
                           & (exc < lo + int(self.shard_sizes[s]))] - lo
            out.append(lo + shift_positions(pos, exc_here))
        return np.concatenate(out) if out else np.empty(0, np.int64)
