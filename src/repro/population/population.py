"""The ``Population`` facade: what ``run_federated(population=...)`` takes.

Duck-types the slice of ``FederatedData`` the FL loop actually touches —
``n_clients`` / ``clients[cid]`` / ``test_x`` / ``test_y`` /
``sample_cohort`` / ``client_n`` — but backed by the three-tier store and
the hierarchical sampler, so the loop's per-round cost and the process's
peak host memory are O(cohort) and O(warm cap) whatever the population
size.  ``Population.from_federated(data, n_shards=1)`` wraps an eager
dataset for the equivalence suites: with one shard the cohort sequence is
bit-identical to the flat loop's.
"""
from __future__ import annotations

from typing import Any, Iterable, Optional

import numpy as np

from repro.core.algorithms import Algorithm
from repro.population.placement import HostPlacement
from repro.population.sampling import HierarchicalSampler
from repro.population.sources import (ClientSource, InMemorySource,
                                      SyntheticClientSource)
from repro.population.store import ClientStateStore, PopulationStore


class _ClientsView:
    """``population.clients[cid]`` — the lazy stand-in for the eager
    ``FederatedData.clients`` list (indexing materializes through the
    warm tier; no other list behavior is supported on purpose)."""

    def __init__(self, store: PopulationStore):
        self._store = store

    def __getitem__(self, cid: int):
        return self._store.get(int(cid))

    def __len__(self) -> int:
        return self._store.n_clients


class Population:
    """A client population the FL loop can sample and materialize lazily.

    Args:
      source: the cold tier (``repro.population.sources``).
      test_x/test_y: the server-side eval split (always eager — it is one
        array, not a population).
      warm_cap: max materialized clients host-side (None = unbounded; the
        1M bench and any real cross-device run should set it).
      state_warm_cap: same cap for MUTABLE per-client algorithm states
        (defaults to ``warm_cap``); evicted states spill to
        ``state_dir`` (a temp dir when unset) and reload on re-sample.
      placement: multi-host ownership (``repro.population.placement``).
        ``warm_cap``/``state_warm_cap`` are GLOBAL figures — with
        ``n_hosts`` hosts each process keeps ``warm_cap // n_hosts``;
        the sampler still draws over the full population on every host
        (bit-identical streams), this host just materializes only the
        clients whose shard it owns.  ``n_hosts == 1`` (and ``None``)
        leave every path exactly as before.
    """

    def __init__(self, source: ClientSource, test_x, test_y, *,
                 warm_cap: Optional[int] = None,
                 state_warm_cap: Optional[int] = None,
                 state_dir: Optional[str] = None,
                 placement: Optional[HostPlacement] = None):
        self.placement = placement
        if placement is not None:
            warm_cap = placement.split_cap(warm_cap)
            if state_warm_cap is not None:
                state_warm_cap = placement.split_cap(state_warm_cap)
        self.store = PopulationStore(source, warm_cap=warm_cap)
        self.sampler = HierarchicalSampler(source.shard_sizes)
        self.clients = _ClientsView(self.store)
        self.test_x = np.asarray(test_x)
        self.test_y = np.asarray(test_y)
        self.state_warm_cap = (state_warm_cap if state_warm_cap is not None
                               else warm_cap)
        self.state_dir = state_dir
        self.state_store: Optional[ClientStateStore] = None

    # -- FederatedData surface -------------------------------------------
    @property
    def n_clients(self) -> int:
        return self.store.n_clients

    @property
    def n_shards(self) -> int:
        return self.sampler.n_shards

    def client_n(self, cid: int) -> int:
        return self.store.client_n(cid)

    def max_client_n(self) -> int:
        fn = getattr(self.store.source, "max_client_n", None)
        if fn is not None:
            return int(fn())
        return int(max(self.store.source.client_n(c)
                       for c in range(self.n_clients)))

    def sample_cohort(self, rng: np.random.Generator, k: int,
                      exclude: Optional[Iterable[int]] = None) -> np.ndarray:
        return self.sampler.sample(rng, k, exclude)

    # -- multi-host placement ---------------------------------------------
    @property
    def multihost(self) -> bool:
        return self.placement is not None and self.placement.n_hosts > 1

    def owned(self, cid: int) -> bool:
        """Does THIS host's warm/hot tier own client ``cid``?"""
        if self.placement is None:
            return True
        return self.placement.owns_shard(self.sampler.shard_of(int(cid)))

    def probe_client(self):
        """Client 0's data straight from the cold source — shape probing
        on a non-owner host must not pull an unowned client into the
        warm tier."""
        return self.store.source.client(0)

    # -- loop wiring ------------------------------------------------------
    def make_client_states(self, algo: Algorithm,
                           global_params: Any) -> ClientStateStore:
        """The lazy replacement for the eager per-client state dict.

        Captures the INITIAL global params (exactly what the eager dict
        was built from); stateless algorithms re-init on read and store
        nothing, stateful ones get the warm-LRU + disk-spill tiers."""
        mutable = (type(algo).update_client_state
                   is not Algorithm.update_client_state)
        self.state_store = ClientStateStore(
            lambda cid: algo.init_client_state(cid, global_params),
            mutable=mutable, warm_cap=self.state_warm_cap,
            spill_dir=self.state_dir, pinned=self.store.pinned)
        return self.state_store

    def attach_hot(self, slab_store) -> None:
        self.store.attach_hot(slab_store)

    def pin(self, cids: Iterable[int]) -> None:
        self.store.pin(cids)

    def unpin(self, cids: Iterable[int]) -> None:
        self.store.unpin(cids)

    def stats(self) -> dict:
        out = dict(self.store.stats(), n_shards=self.sampler.n_shards)
        if self.state_store is not None:
            out.update(self.state_store.stats())
        if self.placement is not None:
            out["host_id"] = self.placement.host_id
            out["n_hosts"] = self.placement.n_hosts
        return out

    # -- constructors -----------------------------------------------------
    @classmethod
    def from_federated(cls, data, n_shards: int = 1, **kw) -> "Population":
        """Wrap an eager ``FederatedData`` (equivalence-suite bridge)."""
        return cls(InMemorySource(data.clients, n_shards=n_shards),
                   data.test_x, data.test_y, **kw)

    @classmethod
    def synthetic(cls, n_clients: int, *, n_test: int = 256, seed: int = 0,
                  shard_size: int = 4096, warm_cap: Optional[int] = 256,
                  placement: Optional[HostPlacement] = None,
                  **source_kw) -> "Population":
        """A seeded synthetic population (the million-client bench)."""
        src = SyntheticClientSource(n_clients, seed=seed,
                                    shard_size=shard_size, **source_kw)
        test_x, test_y = src.test_set(n_test)
        return cls(src, test_x, test_y, warm_cap=warm_cap,
                   placement=placement)
