"""Multi-host placement for the population tier.

One machine stops being the bound: with ``HostPlacement(host_id, n_hosts)``
attached to a ``Population``, every host runs the SAME sampler draws (the
numpy generator streams stay in lockstep — see ``fl_loop._run_multihost``)
but materializes only the cohort slice it owns.  Ownership is by shard:

    host(cid) = shard_of(cid) % n_hosts

so a host's warm LRU holds only clients from its own shard subset and is
capped at ``warm_cap // n_hosts`` — the per-host memory bound.  After the
local slice trains, hosts exchange their uploads through a filesystem
allgather (atomic write-to-temp + ``os.replace``, then poll — the
``checkpoint.io`` idiom, safe because a visible file is always complete)
and every host performs the identical server update on the full
cohort-ordered upload list, so global state never diverges across hosts.

The exchange payloads ride the self-describing ``checkpoint.recovery``
serializer (dict/list/tuple/array/scalar nests), one ``.npz`` per
(round, host) with the msgpack spec embedded, so uploads, weights, losses
and telemetry all travel in a single atomic file.  Payload size is
O(cohort slice), never O(population).

This module is transport only — it does not import jax, so a coordinator
script can construct placements before device initialization.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Optional

import msgpack
import numpy as np

from repro.checkpoint.recovery import _decode, _encode

_SPEC_KEY = "__spec__"


@dataclasses.dataclass(frozen=True)
class HostPlacement:
    """Which slice of the population this process owns.

    Args:
      host_id: this process's rank in ``[0, n_hosts)``.
      n_hosts: total participating processes.  ``n_hosts == 1`` is inert —
        every code path reduces to the single-host behavior bit-for-bit.
      exchange_dir: shared directory for the cross-host upload exchange
        (required when ``n_hosts > 1``; typically NFS or, for the emulated
        2-process topology, a tmpdir both workers see).
      timeout_s: how long to wait for a peer's round payload before
        declaring the topology dead.

    ``stats`` accumulates exchange telemetry across the run (exchanges,
    polled waits, seconds spent waiting, deadline misses and the last
    missing host set) — it is excluded from equality/repr so placements
    still compare by topology, and it surfaces on
    ``History.telemetry["population"]["hosts"]`` so a slow NFS exchange
    is diagnosable from the run record.
    """

    host_id: int
    n_hosts: int
    exchange_dir: Optional[str] = None
    timeout_s: float = 300.0
    poll_s: float = 0.02
    stats: dict = dataclasses.field(default_factory=dict, compare=False,
                                    repr=False)

    def __post_init__(self):
        if self.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.n_hosts}")
        if not (0 <= self.host_id < self.n_hosts):
            raise ValueError(f"host_id {self.host_id} out of range "
                             f"[0, {self.n_hosts})")
        if self.n_hosts > 1 and not self.exchange_dir:
            raise ValueError("n_hosts > 1 needs exchange_dir= (a directory "
                             "every host can read and write)")

    def owns_shard(self, shard: int) -> bool:
        return shard % self.n_hosts == self.host_id

    def split_cap(self, cap: Optional[int]) -> Optional[int]:
        """A global warm cap divided into this host's share."""
        if cap is None:
            return None
        return max(1, cap // self.n_hosts)


# ---------------------------------------------------------------------------
# filesystem allgather
# ---------------------------------------------------------------------------

def _payload_path(exchange_dir: str, tag: str, host: int) -> str:
    return os.path.join(exchange_dir, f"{tag}_host{host:03d}.npz")


def publish(placement: HostPlacement, tag: str, obj: Any) -> str:
    """Write this host's payload for ``tag`` (one file, atomic)."""
    arrays: dict[str, np.ndarray] = {}
    spec = _encode(obj, arrays)
    arrays[_SPEC_KEY] = np.frombuffer(msgpack.packb(spec), np.uint8)
    path = _payload_path(placement.exchange_dir, tag, placement.host_id)
    os.makedirs(placement.exchange_dir, exist_ok=True)
    tmp = f"{path}.tmp{placement.host_id}"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)           # readers never see a partial file
    return path


def _read_payload(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    spec = msgpack.unpackb(arrays.pop(_SPEC_KEY).tobytes())
    return _decode(spec, arrays)


def _bump(placement: HostPlacement, key: str, by: float = 1) -> None:
    placement.stats[key] = placement.stats.get(key, 0) + by


def _gather(placement: HostPlacement, tag: str, obj: Any,
            strict: bool, skip_wait=()) -> tuple[list, tuple[int, ...]]:
    """Publish ``obj`` and poll every host's ``tag`` payload round-robin
    until all land or the deadline passes.  Returns ``(payloads, missing)``
    with ``payloads[h] is None`` for each host in ``missing``.  Hosts in
    ``skip_wait`` (already declared crashed under the crash-stop
    assumption) get exactly one existence check and no polling — a dead
    peer must not cost a full timeout on every subsequent exchange."""
    publish(placement, tag, obj)
    _bump(placement, "exchanges")
    pending = set(range(placement.n_hosts))
    got: set = set()
    out: list = [None] * placement.n_hosts
    t0 = time.monotonic()
    deadline = t0 + placement.timeout_s
    polled = False
    while pending:
        for h in sorted(pending):
            path = _payload_path(placement.exchange_dir, tag, h)
            if os.path.exists(path):
                out[h] = _read_payload(path)
                got.add(h)
                pending.discard(h)
        pending.difference_update(skip_wait)
        if not pending:
            break
        if time.monotonic() > deadline:
            break
        polled = True
        time.sleep(placement.poll_s)
    if polled:
        _bump(placement, "waits")
        _bump(placement, "wait_s", round(time.monotonic() - t0, 6))
    missing = tuple(h for h in range(placement.n_hosts) if h not in got)
    if missing:
        _bump(placement, "timeouts")
        placement.stats["last_missing"] = list(missing)
        placement.stats["last_missing_tag"] = tag
        if strict:
            raise RuntimeError(
                f"multi-host exchange {tag!r} timed out after "
                f"{placement.timeout_s:.0f}s: missing host(s) "
                f"{list(missing)} of {placement.n_hosts} "
                f"(exchange_dir={placement.exchange_dir}) — are the "
                f"workers alive?")
    return out, missing


def allgather(placement: HostPlacement, tag: str, obj: Any) -> list:
    """Publish ``obj`` and block until every host's ``tag`` payload lands;
    returns the payloads indexed by host id (this host's own round-trips
    through its file too, so every host consumes byte-identical inputs).
    Raises naming ALL missing hosts and the exchange tag on timeout."""
    out, _ = _gather(placement, tag, obj, strict=True)
    return out


def allgather_partial(placement: HostPlacement, tag: str, obj: Any,
                      skip_wait=()) -> tuple[list, tuple[int, ...]]:
    """``allgather`` that degrades instead of raising: a host missing the
    deadline is reported in ``missing`` (its payload slot is ``None``) so
    the fault-tolerant round can treat it as crashed rather than hanging.
    Deterministic across survivors under the crash-stop assumption: a dead
    host never publishes, so every survivor resolves the same missing set
    (given a timeout comfortably above the live hosts' skew).  Hosts in
    ``skip_wait`` are checked once but never polled for."""
    return _gather(placement, tag, obj, strict=False, skip_wait=skip_wait)


# ---------------------------------------------------------------------------
# coordinated resume
# ---------------------------------------------------------------------------

def _avail_tag() -> str:
    return "resume-avail"


def resume_barrier(placement: HostPlacement,
                   avail: Optional[int]) -> Optional[int]:
    """Phase 1 of the coordinated resume: exchange each host's newest
    loadable checkpoint round and agree on the common restore point.

    Returns ``min`` over the hosts' rounds — the latest round EVERY host
    can load (a host that checkpointed further ahead still has the earlier
    file; checkpoints are never deleted) — or ``None`` when every host is
    fresh.  A mix of fresh and resumable hosts raises: restoring some
    hosts mid-run while others start from round 0 can never reconverge.
    """
    got = allgather(placement, _avail_tag(), {"avail": avail})
    vals = [g["avail"] for g in got]
    if all(v is None for v in vals):
        return None
    if any(v is None for v in vals):
        fresh = [h for h, v in enumerate(vals) if v is None]
        raise RuntimeError(
            f"coordinated resume: host(s) {fresh} have no loadable "
            f"checkpoint but peers report rounds "
            f"{[v for v in vals if v is not None]} — mixed fresh/resume "
            f"states cannot reconverge; clear or repair the checkpoint "
            f"dirs")
    return min(int(v) for v in vals)


def confirm_resume(placement: HostPlacement, common: Optional[int],
                   meta: dict) -> None:
    """Phase 2: every host publishes what it actually restored (round,
    version, algo, ...) under a restore-point-tagged barrier and validates
    the peers restored the very same state before the first wave runs.

    The tag embeds the common round, so a host that computed a DIFFERENT
    restore point (e.g. from a stale phase-1 file of an interrupted
    earlier resume) waits on a tag nobody publishes and fails loudly at
    the timeout instead of silently diverging.  Completing this barrier
    also proves every peer consumed this host's phase-1 payload, so the
    phase-1 file is retired here — the next resume starts clean.
    """
    tag = ("resume-ok-fresh" if common is None
           else f"resume-ok-r{common:06d}")
    got = allgather(placement, tag, dict(meta))
    mine = got[placement.host_id]
    for h, g in enumerate(got):
        if g != mine:
            raise RuntimeError(
                f"coordinated resume diverged: host {placement.host_id} "
                f"restored {mine} but host {h} restored {g} — refusing "
                f"to run the first wave from inconsistent state")
    try:
        os.remove(_payload_path(placement.exchange_dir, _avail_tag(),
                                placement.host_id))
    except OSError:
        pass


def clear_host_payloads(placement: HostPlacement,
                        keep_prefixes: tuple = ("resume-",)) -> int:
    """Delete every exchange payload THIS host has published (wave/round
    files; resume-barrier files are kept).  Called on resume before the
    confirm barrier: a surviving host may have published waves past the
    restore point whose content assumed the dead peer stayed dead, and a
    stale file must never satisfy a peer's existence poll once the replay
    diverges from that history.  Own files only — each host retires its
    own stale state, and the confirm barrier orders every deletion before
    any post-resume read."""
    d = placement.exchange_dir
    if not d or not os.path.isdir(d):
        return 0
    suffix = f"_host{placement.host_id:03d}.npz"
    removed = 0
    for name in sorted(os.listdir(d)):
        if not name.endswith(suffix):
            continue
        if any(name.startswith(p) for p in keep_prefixes):
            continue
        try:
            os.remove(os.path.join(d, name))
            removed += 1
        except OSError:
            pass
    return removed


def peak_rss_mb() -> float:
    """This process's peak resident set (VmHWM), in MB."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except (OSError, ValueError, IndexError):
        pass
    return float("nan")
