"""Multi-host placement for the population tier.

One machine stops being the bound: with ``HostPlacement(host_id, n_hosts)``
attached to a ``Population``, every host runs the SAME sampler draws (the
numpy generator streams stay in lockstep — see ``fl_loop._run_multihost``)
but materializes only the cohort slice it owns.  Ownership is by shard:

    host(cid) = shard_of(cid) % n_hosts

so a host's warm LRU holds only clients from its own shard subset and is
capped at ``warm_cap // n_hosts`` — the per-host memory bound.  After the
local slice trains, hosts exchange their uploads through a filesystem
allgather (atomic write-to-temp + ``os.replace``, then poll — the
``checkpoint.io`` idiom, safe because a visible file is always complete)
and every host performs the identical server update on the full
cohort-ordered upload list, so global state never diverges across hosts.

The exchange payloads ride the self-describing ``checkpoint.recovery``
serializer (dict/list/tuple/array/scalar nests), one ``.npz`` per
(round, host) with the msgpack spec embedded, so uploads, weights, losses
and telemetry all travel in a single atomic file.  Payload size is
O(cohort slice), never O(population).

This module is transport only — it does not import jax, so a coordinator
script can construct placements before device initialization.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Optional

import msgpack
import numpy as np

from repro.checkpoint.recovery import _decode, _encode

_SPEC_KEY = "__spec__"


@dataclasses.dataclass(frozen=True)
class HostPlacement:
    """Which slice of the population this process owns.

    Args:
      host_id: this process's rank in ``[0, n_hosts)``.
      n_hosts: total participating processes.  ``n_hosts == 1`` is inert —
        every code path reduces to the single-host behavior bit-for-bit.
      exchange_dir: shared directory for the cross-host upload exchange
        (required when ``n_hosts > 1``; typically NFS or, for the emulated
        2-process topology, a tmpdir both workers see).
      timeout_s: how long to wait for a peer's round payload before
        declaring the topology dead.
    """

    host_id: int
    n_hosts: int
    exchange_dir: Optional[str] = None
    timeout_s: float = 300.0
    poll_s: float = 0.02

    def __post_init__(self):
        if self.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.n_hosts}")
        if not (0 <= self.host_id < self.n_hosts):
            raise ValueError(f"host_id {self.host_id} out of range "
                             f"[0, {self.n_hosts})")
        if self.n_hosts > 1 and not self.exchange_dir:
            raise ValueError("n_hosts > 1 needs exchange_dir= (a directory "
                             "every host can read and write)")

    def owns_shard(self, shard: int) -> bool:
        return shard % self.n_hosts == self.host_id

    def split_cap(self, cap: Optional[int]) -> Optional[int]:
        """A global warm cap divided into this host's share."""
        if cap is None:
            return None
        return max(1, cap // self.n_hosts)


# ---------------------------------------------------------------------------
# filesystem allgather
# ---------------------------------------------------------------------------

def _payload_path(exchange_dir: str, tag: str, host: int) -> str:
    return os.path.join(exchange_dir, f"{tag}_host{host:03d}.npz")


def publish(placement: HostPlacement, tag: str, obj: Any) -> str:
    """Write this host's payload for ``tag`` (one file, atomic)."""
    arrays: dict[str, np.ndarray] = {}
    spec = _encode(obj, arrays)
    arrays[_SPEC_KEY] = np.frombuffer(msgpack.packb(spec), np.uint8)
    path = _payload_path(placement.exchange_dir, tag, placement.host_id)
    os.makedirs(placement.exchange_dir, exist_ok=True)
    tmp = f"{path}.tmp{placement.host_id}"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)           # readers never see a partial file
    return path


def _read_payload(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    spec = msgpack.unpackb(arrays.pop(_SPEC_KEY).tobytes())
    return _decode(spec, arrays)


def allgather(placement: HostPlacement, tag: str, obj: Any) -> list:
    """Publish ``obj`` and block until every host's ``tag`` payload lands;
    returns the payloads indexed by host id (this host's own round-trips
    through its file too, so every host consumes byte-identical inputs)."""
    publish(placement, tag, obj)
    out = []
    deadline = time.monotonic() + placement.timeout_s
    for h in range(placement.n_hosts):
        path = _payload_path(placement.exchange_dir, tag, h)
        while not os.path.exists(path):
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"multi-host exchange timed out after "
                    f"{placement.timeout_s:.0f}s waiting for host {h} "
                    f"({path}) — is the worker alive?")
            time.sleep(placement.poll_s)
        out.append(_read_payload(path))
    return out


def peak_rss_mb() -> float:
    """This process's peak resident set (VmHWM), in MB."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except (OSError, ValueError, IndexError):
        pass
    return float("nan")
