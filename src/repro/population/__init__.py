"""Population tier: out-of-core client store + O(cohort) sampling.

The cross-device regime the paper evaluates under (Table 5: large
populations, small sampled cohorts) at the scale the ROADMAP targets:
millions of registered clients, with host memory bounded by a warm-tier
cap instead of the population size.  See ``population.py`` for the facade
the FL loop consumes, ``sources.py`` for the cold tier, ``store.py`` for
the warm/state tiers, and ``sampling.py`` for the two-stage cohort draw.
"""
from repro.population.placement import (HostPlacement, allgather,
                                        allgather_partial,
                                        clear_host_payloads, confirm_resume,
                                        peak_rss_mb, resume_barrier)
from repro.population.population import Population
from repro.population.sampling import HierarchicalSampler, shift_positions
from repro.population.sources import (ClientSource, DiskShardSource,
                                      InMemorySource, SyntheticClientSource,
                                      even_shard_sizes,
                                      write_population_shards)
from repro.population.store import ClientStateStore, PopulationStore

__all__ = [
    "Population", "HierarchicalSampler", "shift_positions", "ClientSource",
    "DiskShardSource", "InMemorySource", "SyntheticClientSource",
    "even_shard_sizes", "write_population_shards", "ClientStateStore",
    "PopulationStore", "HostPlacement", "allgather", "allgather_partial",
    "resume_barrier", "confirm_resume", "clear_host_payloads",
    "peak_rss_mb",
]
