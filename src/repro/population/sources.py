"""Cold-tier client sources: where a client's shard comes from.

A ``ClientSource`` materializes one client on demand — the population tier
(``repro.population.store``) keeps a bounded warm/hot working set on top,
so peak host memory is O(warm cap), never O(population).  Three sources:

    InMemorySource        wraps an eager ``list[ClientData]`` (the historical
                          ``FederatedData`` layout) — the equivalence-suite
                          bridge, not a scaling route
    SyntheticClientSource seeded per-client generation: client ``cid`` is a
                          pure function of (seed, cid), nothing is stored —
                          the million-client bench's population
    DiskShardSource       per-shard ``.npy`` files opened ``mmap_mode="r"``
                          (written by ``write_population_shards`` with the
                          ``checkpoint.io`` atomic-replace idiom + msgpack
                          meta sidecar) — the out-of-core production layout

Every source exposes ``shard_sizes`` (contiguous client-id ranges — the
geometry ``HierarchicalSampler`` draws over) and ``client_n(cid)`` (the
client's example count WITHOUT materializing its arrays: the async loop
prices local work for 1M clients from sizes alone).
"""
from __future__ import annotations

import collections
import os
from typing import Iterator, Protocol, runtime_checkable

import msgpack
import numpy as np

from repro.data.pipeline import ClientData

_META_NAME = "population.meta"


def _check_cid(cid: int, n_clients: int) -> None:
    """Bounds-check a client id — every source raises the same IndexError
    (``client(-1)`` must never wrap via Python negative indexing, and a
    synthetic source must never mint phantom clients past the census)."""
    if not (0 <= cid < n_clients):
        raise IndexError(f"client id {cid} out of range "
                         f"[0, {n_clients})")


def even_shard_sizes(n_clients: int, shard_size: int) -> np.ndarray:
    """Contiguous shards of ``shard_size`` clients (last one partial)."""
    if n_clients <= 0 or shard_size <= 0:
        raise ValueError(f"need positive n_clients/shard_size, got "
                         f"{n_clients}/{shard_size}")
    n_shards = -(-n_clients // shard_size)
    sizes = np.full(n_shards, shard_size, np.int64)
    sizes[-1] = n_clients - shard_size * (n_shards - 1)
    return sizes


@runtime_checkable
class ClientSource(Protocol):
    """Lazy per-client data: the population store's cold tier."""

    n_clients: int
    shard_sizes: np.ndarray     # contiguous client-id ranges

    def client(self, cid: int) -> ClientData:
        """Materialize client ``cid``'s full shard (fresh host arrays)."""
        ...

    def client_n(self, cid: int) -> int:
        """``client(cid).n`` without materializing the arrays."""
        ...


class InMemorySource:
    """Adapter over an eager client list (``FederatedData.clients``)."""

    def __init__(self, clients: list[ClientData], n_shards: int = 1):
        if not clients:
            raise ValueError("InMemorySource needs at least one client")
        self.clients = clients
        self.n_clients = len(clients)
        n_shards = min(n_shards, self.n_clients)
        self.shard_sizes = even_shard_sizes(
            self.n_clients, -(-self.n_clients // n_shards))

    def client(self, cid: int) -> ClientData:
        _check_cid(cid, self.n_clients)
        return self.clients[cid]

    def client_n(self, cid: int) -> int:
        _check_cid(cid, self.n_clients)
        return self.clients[cid].n

    def max_client_n(self) -> int:
        return int(max(c.n for c in self.clients))


class SyntheticClientSource:
    """Million-client populations from a seed: client ``cid`` is generated
    on demand from an independent child stream ``(seed, cid)`` of numpy's
    SeedSequence tree, so any client is reproducible in isolation and the
    source holds nothing but the (num_classes, dim) class-mean matrix.

    The task is the executor benchmarks' rotated-Gaussian-blob tabular
    task (``repro.data.synthetic.SyntheticTabularTask``) with per-client
    example counts drawn uniformly from ``[min_n, max_n]`` — ragged, like
    a real cross-device population.
    """

    def __init__(self, n_clients: int, *, num_classes: int = 10,
                 dim: int = 16, min_n: int = 16, max_n: int = 48,
                 noise: float = 1.0, seed: int = 0, shard_size: int = 4096):
        if not (1 <= min_n <= max_n):
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"{min_n}/{max_n}")
        self.n_clients = n_clients
        self.num_classes = num_classes
        self.dim = dim
        self.min_n, self.max_n = min_n, max_n
        self.noise = noise
        self.seed = seed
        self.shard_sizes = even_shard_sizes(n_clients, shard_size)
        # shared class geometry (fixed by the task seed, like
        # SyntheticTabularTask: train/test/clients all see the same means)
        mrng = np.random.default_rng(seed + 77)
        means = mrng.normal(0, 1, size=(num_classes, dim))
        means *= 2.0 / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-9)
        rot, _ = np.linalg.qr(mrng.normal(0, 1, (dim, dim)))
        self._means, self._rot = means, rot

    def _rng(self, cid: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(cid,)))

    def client_n(self, cid: int) -> int:
        # the size is the client stream's FIRST draw, so it is knowable
        # without generating the feature arrays
        _check_cid(cid, self.n_clients)
        return int(self._rng(cid).integers(self.min_n, self.max_n + 1))

    def max_client_n(self) -> int:
        # sizes are uniform over [min_n, max_n]: the bound is exact
        # without touching a single client stream
        return self.max_n

    def client(self, cid: int) -> ClientData:
        _check_cid(cid, self.n_clients)
        rng = self._rng(cid)
        n = int(rng.integers(self.min_n, self.max_n + 1))
        labels = rng.integers(0, self.num_classes, size=n)
        x = self._means[labels] + rng.normal(0, self.noise, (n, self.dim))
        return ClientData((x @ self._rot).astype(np.float32),
                          labels.astype(np.int64))

    def test_set(self, n_test: int) -> tuple[np.ndarray, np.ndarray]:
        """A held-out eval split from the same class geometry."""
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed,
                                   spawn_key=(0x7E57,)))
        labels = rng.integers(0, self.num_classes, size=n_test)
        x = self._means[labels] + rng.normal(0, self.noise,
                                             (n_test, self.dim))
        return ((x @ self._rot).astype(np.float32),
                labels.astype(np.int64))


# ---------------------------------------------------------------------------
# on-disk shards
# ---------------------------------------------------------------------------

def _shard_paths(root: str, s: int) -> tuple[str, str, str]:
    return (os.path.join(root, f"shard_{s:05d}_x.npy"),
            os.path.join(root, f"shard_{s:05d}_y.npy"),
            os.path.join(root, f"shard_{s:05d}_off.npy"))


def write_population_shards(root: str, clients: Iterator[ClientData], *,
                            shard_size: int = 1024) -> dict:
    """Write a client stream as per-shard memmap-able ``.npy`` triples.

    Shard ``s`` holds its clients' examples row-concatenated
    (``shard_s_x.npy`` / ``shard_s_y.npy``) plus an int64 offsets vector
    (``shard_s_off.npy``, length ``clients_in_shard + 1``); a msgpack
    ``population.meta`` sidecar records the shard sizes.  Files land via
    write-to-temp + ``os.replace`` (the ``checkpoint.io`` idiom), so a
    crash mid-write never leaves a plausible-looking partial shard.
    Returns the meta dict.
    """
    os.makedirs(root, exist_ok=True)

    def _atomic_save(path: str, arr: np.ndarray) -> None:
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:       # file object: np.save appends no
            np.save(f, arr)              # suffix, so the replace target is
        os.replace(tmp, path)            # exactly what _shard() will open

    shard_sizes: list[int] = []
    s = 0
    pending_x: list[np.ndarray] = []
    pending_y: list[np.ndarray] = []

    def _flush() -> None:
        nonlocal s, pending_x, pending_y
        if not pending_x:
            return
        px, py, poff = _shard_paths(root, s)
        off = np.concatenate(
            [np.zeros(1, np.int64),
             np.cumsum([len(y) for y in pending_y], dtype=np.int64)])
        _atomic_save(px, np.concatenate(pending_x))
        _atomic_save(py, np.concatenate(pending_y).astype(np.int64))
        _atomic_save(poff, off)
        shard_sizes.append(len(pending_x))
        s += 1
        pending_x, pending_y = [], []

    for c in clients:
        pending_x.append(np.asarray(c.x))
        pending_y.append(np.asarray(c.y))
        if len(pending_x) == shard_size:
            _flush()
    _flush()
    if not shard_sizes:
        raise ValueError("write_population_shards: empty client stream")
    meta = {"n_clients": int(sum(shard_sizes)),
            "shard_sizes": [int(z) for z in shard_sizes]}
    tmp = os.path.join(root, _META_NAME + ".tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(meta))
    os.replace(tmp, os.path.join(root, _META_NAME))
    return meta


class DiskShardSource:
    """Out-of-core population: clients sliced from memmapped shard files.

    ``np.load(mmap_mode="r")`` keeps shard bytes on disk until a client's
    rows are actually touched; an LRU of ``max_open`` open shard handles
    bounds file descriptors however the sampler hops between shards.
    ``client()`` copies the client's rows out of the map, so returned
    ``ClientData`` never pins a shard file open.
    """

    def __init__(self, root: str, max_open: int = 8):
        meta_path = os.path.join(root, _META_NAME)
        if not os.path.exists(meta_path):
            raise FileNotFoundError(
                f"no {_META_NAME} under {root!r} — write the population "
                f"with repro.population.write_population_shards first")
        with open(meta_path, "rb") as f:
            meta = msgpack.unpackb(f.read())
        self.root = root
        self.n_clients = int(meta["n_clients"])
        self.shard_sizes = np.asarray(meta["shard_sizes"], np.int64)
        self.starts = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(self.shard_sizes)])
        self.max_open = max_open
        self._open: "collections.OrderedDict[int, tuple]" = \
            collections.OrderedDict()
        self.shard_opens = 0        # cold-tier file opens (telemetry)

    def _shard(self, s: int) -> tuple:
        handle = self._open.get(s)
        if handle is not None:
            self._open.move_to_end(s)
            return handle
        px, py, poff = _shard_paths(self.root, s)
        handle = (np.load(px, mmap_mode="r"), np.load(py, mmap_mode="r"),
                  np.load(poff))
        self.shard_opens += 1
        self._open[s] = handle
        while len(self._open) > self.max_open:
            self._open.popitem(last=False)
        return handle

    def _locate(self, cid: int) -> tuple[int, int]:
        _check_cid(cid, self.n_clients)
        s = int(np.searchsorted(self.starts, cid, side="right") - 1)
        return s, cid - int(self.starts[s])

    def client_n(self, cid: int) -> int:
        s, i = self._locate(cid)
        off = self._shard(s)[2]
        return int(off[i + 1] - off[i])

    def max_client_n(self) -> int:
        """Largest client from the per-shard offset tables alone — the
        offset vectors are tiny and the x/y maps are lazy, so shard
        payload bytes stay cold.  Goes through ``_shard`` so the handle
        LRU stays authoritative and ``shard_opens`` counts these opens."""
        best = 0
        for s in range(len(self.shard_sizes)):
            off = self._shard(s)[2]
            best = max(best, int(np.max(np.diff(off))))
        return best

    def client(self, cid: int) -> ClientData:
        s, i = self._locate(cid)
        x, y, off = self._shard(s)
        lo, hi = int(off[i]), int(off[i + 1])
        return ClientData(np.array(x[lo:hi]), np.array(y[lo:hi]))
