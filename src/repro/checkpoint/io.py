"""Checkpointing: pytrees <-> .npz (+ msgpack metadata sidecar).

Layout: ``<dir>/round_000123.npz`` with flattened '/'-joined key paths, and
``<dir>/round_000123.meta`` (msgpack: round, metrics, config name).  Restart
resumes from the latest round file; this is what the FL server uses to
persist its global-model buffer.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    # npz can't round-trip ml_dtypes (bf16 etc.): store the raw bits and a
    # dtype map so load can reinterpret them
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    for k, v in flat.items():
        if v.dtype.kind == "V" or str(v.dtype) == "bfloat16":
            flat[k] = v.view(np.uint16) if v.dtype.itemsize == 2 else v
    flat["__dtypes__"] = np.frombuffer(msgpack.packb(dtypes), np.uint8)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if meta is not None:
        with open(re.sub(r"\.npz$", "", path) + ".meta", "wb") as f:
            f.write(msgpack.packb(meta))


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (dtypes/shapes must match)."""
    if not path.endswith(".npz"):
        path += ".npz"
    with np.load(path) as data:
        dtypes = {}
        if "__dtypes__" in data:
            dtypes = msgpack.unpackb(data["__dtypes__"].tobytes())
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kpath, leaf in flat_like:
            key = "/".join(_key_str(p) for p in kpath)
            arr = data[key]
            saved_dt = dtypes.get(key, str(arr.dtype))
            if saved_dt == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def load_meta(path: str) -> dict:
    with open(re.sub(r"\.npz$", "", path) + ".meta", "rb") as f:
        return msgpack.unpackb(f.read())


def save_round(ckpt_dir: str, rnd: int, tree: Any, meta: dict | None = None) -> str:
    path = os.path.join(ckpt_dir, f"round_{rnd:06d}.npz")
    save_pytree(path, tree, meta={"round": rnd, **(meta or {})})
    return path


def load_latest(ckpt_dir: str, like: Any) -> tuple[Any, int] | None:
    if not os.path.isdir(ckpt_dir):
        return None
    rounds = sorted(
        int(m.group(1)) for f in os.listdir(ckpt_dir)
        if (m := re.match(r"round_(\d+)\.npz$", f)))
    if not rounds:
        return None
    rnd = rounds[-1]
    tree = load_pytree(os.path.join(ckpt_dir, f"round_{rnd:06d}.npz"), like)
    return tree, rnd
