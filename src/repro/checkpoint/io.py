"""Checkpointing: pytrees <-> .npz (+ msgpack metadata sidecar).

Layout: ``<dir>/round_000123.npz`` with flattened '/'-joined key paths, and
``<dir>/round_000123.meta`` (msgpack: round, metrics, config name).  Restart
resumes from the latest round file; this is what the FL server uses to
persist its global-model buffer.
"""
from __future__ import annotations

import logging
import os
import re
import zipfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_LOG = logging.getLogger("repro.checkpoint")


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    # refuse to persist NaN/Inf: a poisoned run must never leave behind a
    # structurally-valid checkpoint that a later resume would trust —
    # load_latest can skip a TORN file, but not a well-formed toxic one
    for key, arr in flat.items():
        if arr.dtype.kind == "f" and not np.isfinite(arr).all():
            raise ValueError(
                f"save_pytree({path!r}): non-finite values at leaf "
                f"{key!r} — refusing to write a corrupt checkpoint")
    # npz can't round-trip ml_dtypes (bf16 etc.): store the raw bits and a
    # dtype map so load can reinterpret them
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    for k, v in flat.items():
        if v.dtype.kind == "V" or str(v.dtype) == "bfloat16":
            flat[k] = v.view(np.uint16) if v.dtype.itemsize == 2 else v
    flat["__dtypes__"] = np.frombuffer(msgpack.packb(dtypes), np.uint8)
    final = path if path.endswith(".npz") else path + ".npz"
    # write-to-temp + atomic replace: a crash mid-save leaves a .tmp file
    # (ignored by load_latest's round pattern), never a truncated .npz
    # that a later restart would trip over
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)
    if meta is not None:
        mtmp = re.sub(r"\.npz$", "", path) + ".meta.tmp"
        with open(mtmp, "wb") as f:
            f.write(msgpack.packb(meta))
        os.replace(mtmp, mtmp[:-4])


def load_flat(path: str) -> dict[str, np.ndarray]:
    """Raw flattened view of a checkpoint: '/'-joined key -> array, with
    the ``__dtypes__`` sidecar already re-applied (bf16 bits
    reinterpreted).  The self-describing half of ``load_pytree`` — used
    directly by consumers (``checkpoint.recovery``) whose structure is
    recorded in metadata rather than supplied as a ``like`` template."""
    if not path.endswith(".npz"):
        path += ".npz"
    with np.load(path) as data:
        dtypes = {}
        if "__dtypes__" in data:
            dtypes = msgpack.unpackb(data["__dtypes__"].tobytes())
        out = {}
        for key in data.files:
            if key == "__dtypes__":
                continue
            arr = data[key]
            saved_dt = dtypes.get(key, str(arr.dtype))
            if saved_dt == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            out[key] = arr
    return out


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (dtypes/shapes must match)."""
    flat = load_flat(path)
    flat_like, _ = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kpath, leaf in flat_like:
        key = "/".join(_key_str(p) for p in kpath)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def load_meta(path: str) -> dict:
    with open(re.sub(r"\.npz$", "", path) + ".meta", "rb") as f:
        return msgpack.unpackb(f.read())


def save_round(ckpt_dir: str, rnd: int, tree: Any, meta: dict | None = None) -> str:
    path = os.path.join(ckpt_dir, f"round_{rnd:06d}.npz")
    save_pytree(path, tree, meta={"round": rnd, **(meta or {})})
    return path


# what a partial / corrupt round file raises out of np.load + unpack:
# truncated zip central directory (BadZipFile), zero-byte file (EOF/OSError
# variants), a member cut mid-stream (zlib -> OSError subclass), a file
# missing keys or the dtype sidecar (KeyError), or garbage msgpack
CORRUPT_ERRORS = (zipfile.BadZipFile, EOFError, OSError, KeyError,
                  ValueError, msgpack.exceptions.UnpackException)
_CORRUPT_ERRORS = CORRUPT_ERRORS    # historical alias


def latest_loadable(ckpt_dir: str, prefix: str, loader) -> \
        "tuple[Any, int] | None":
    """Walk ``<ckpt_dir>/<prefix>_NNNNNN.npz`` newest-first and return
    ``(loader(path), round)`` for the first file that loads.

    A crash mid-``save_pytree`` historically left a truncated ``.npz``
    that surfaced as an opaque ``BadZipFile``/``EOFError`` deep inside
    ``np.load`` on the next restart.  New saves are atomic (temp +
    replace), but checkpoints written by older code — or torn by the
    filesystem — still exist; any file that fails to load is skipped
    (with a warning naming it), and a clear ``RuntimeError`` is raised
    only when EVERY file is unreadable (silently restarting from scratch
    would discard training history).  Returns ``None`` when no matching
    file exists at all.  This is the shared foundation of both
    ``load_latest`` (plain param trees) and ``checkpoint.recovery``'s
    full run-state resume.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    pat = re.compile(re.escape(prefix) + r"_(\d+)\.npz$")
    rounds = sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                    if (m := pat.match(f)))
    if not rounds:
        return None
    failures: list[str] = []
    for rnd in reversed(rounds):
        path = os.path.join(ckpt_dir, f"{prefix}_{rnd:06d}.npz")
        try:
            return loader(path), rnd
        except CORRUPT_ERRORS as e:
            failures.append(f"{path}: {type(e).__name__}: {e}")
            _LOG.warning("skipping unreadable checkpoint %s (%s: %s)",
                         path, type(e).__name__, e)
    raise RuntimeError(
        "latest_loadable: every %s file in %r is partial or corrupt "
        "(crash mid-save?). Remove the directory to restart from scratch.\n  "
        % (prefix, ckpt_dir) + "\n  ".join(failures))


def load_latest(ckpt_dir: str, like: Any) -> tuple[Any, int] | None:
    """Resume from the newest LOADABLE ``round_*.npz`` (see
    ``latest_loadable`` for the corrupt-skip semantics)."""
    return latest_loadable(ckpt_dir, "round",
                           lambda path: load_pytree(path, like))
