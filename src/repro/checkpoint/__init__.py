from repro.checkpoint.io import (  # noqa: F401
    CORRUPT_ERRORS, latest_loadable, load_flat, load_latest, load_pytree,
    save_pytree, save_round)
from repro.checkpoint.recovery import (  # noqa: F401
    load_latest_state, load_run_state, save_run_state)
