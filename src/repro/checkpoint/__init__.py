from repro.checkpoint.io import save_pytree, load_pytree, save_round, load_latest  # noqa: F401
