"""Mid-run crash recovery: the FULL federated run state <-> disk.

``checkpoint.io`` persists parameter pytrees; resuming a killed run
bit-identically needs strictly more — the numpy sampler state, the jax
PRNG key, the FedGKD ``ModelBuffer`` (models + version counter), the
fault-injector stream, per-client algorithm state and the round records
accumulated so far.  Those pieces are not a fixed-structure pytree (the
teacher buffer grows over early rounds, ``val_losses`` tracks it, rng
states carry 128-bit integers), so a ``like``-template load cannot
reconstruct them.

Instead each ``state_NNNNNN.npz`` is SELF-DESCRIBING: every array leaf is
stored flat under a generated key while a msgpack "spec" in the ``.meta``
sidecar records the container structure (dict/list/tuple), python scalars,
big integers (as decimal strings — msgpack tops out at 64 bits) and
``ModelBuffer`` internals.  ``load_run_state`` folds the two back together
with no template.  Writes go through ``io.save_pytree`` — atomic
temp+replace, bf16-safe, and REFUSING non-finite leaves, so a poisoned run
can never leave a structurally-valid toxic state file behind — and resume
goes through ``io.latest_loadable``, the same newest-first corrupt-file
skipping that ``load_latest`` uses: a file torn by a crash mid-save is
skipped with a warning and the previous round's state restores instead.
"""
from __future__ import annotations

import os
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io
from repro.core.server import ModelBuffer

_I64_MAX = 2 ** 63 - 1


def _encode(obj: Any, arrays: dict) -> Any:
    """Recursively split ``obj`` into a msgpack-safe spec + flat arrays."""
    if isinstance(obj, ModelBuffer):
        return {"k": "modelbuffer", "size": obj.size,
                "versions": list(obj._versions),
                "next_version": obj._next_version,
                "models": [_encode(m, arrays) for m in obj._buf]}
    if isinstance(obj, dict):
        return {"k": "dict", "keys": [_encode(k, arrays) for k in obj],
                "vals": [_encode(v, arrays) for v in obj.values()]}
    if isinstance(obj, (list, tuple)):
        return {"k": "list" if isinstance(obj, list) else "tuple",
                "items": [_encode(v, arrays) for v in obj]}
    if isinstance(obj, np.floating):
        # numpy float scalars are IEEE doubles — exact as python floats;
        # the array path below would round-trip them through jnp's
        # default float32 and silently lose bits (the async sim heap's
        # completion times are np.float64)
        return {"k": "py", "v": float(obj)}
    if isinstance(obj, np.integer):
        v = int(obj)
        if abs(v) > _I64_MAX:
            return {"k": "bigint", "v": str(v)}
        return {"k": "py", "v": v}
    if hasattr(obj, "shape") and hasattr(obj, "dtype"):
        key = f"a{len(arrays)}"
        arrays[key] = np.asarray(obj)
        return {"k": "arr", "ref": key}
    if isinstance(obj, bool) or obj is None or isinstance(obj, (float, str)):
        return {"k": "py", "v": obj}
    if isinstance(obj, (int, np.integer)):
        v = int(obj)
        if abs(v) > _I64_MAX:    # PCG64 state words are 128-bit
            return {"k": "bigint", "v": str(v)}
        return {"k": "py", "v": v}
    raise TypeError(f"run-state serializer: unsupported {type(obj)!r}")


def _decode(spec: Any, arrays: dict) -> Any:
    kind = spec["k"]
    if kind == "modelbuffer":
        buf = ModelBuffer(spec["size"])
        for m, v in zip(spec["models"], spec["versions"]):
            buf._buf.append(_decode(m, arrays))
            buf._versions.append(v)
        buf._next_version = spec["next_version"]
        return buf
    if kind == "dict":
        return {_decode(k, arrays): _decode(v, arrays)
                for k, v in zip(spec["keys"], spec["vals"])}
    if kind == "list":
        return [_decode(v, arrays) for v in spec["items"]]
    if kind == "tuple":
        return tuple(_decode(v, arrays) for v in spec["items"])
    if kind == "arr":
        return jnp.asarray(arrays[spec["ref"]])
    if kind == "py":
        return spec["v"]
    if kind == "bigint":
        return int(spec["v"])
    raise ValueError(f"run-state spec: unknown kind {kind!r}")


def rng_state(rng: np.random.Generator) -> dict:
    """Snapshot a numpy Generator (plain nested dict of ints/strings)."""
    return rng.bit_generator.state


def restore_rng(rng: np.random.Generator, state: dict) -> None:
    rng.bit_generator.state = state


def _state_prefix(host: "int | None" = None) -> str:
    """The state-file prefix: ``state`` single-host (unchanged on disk),
    ``state_hostNNN`` for one rank of a multi-host run — each host owns
    its warm/state tier snapshot, in the same directory, and
    ``io.latest_loadable``'s anchored ``prefix_(\\d+).npz`` pattern keeps
    the two namespaces from ever matching each other."""
    return "state" if host is None else f"state_host{host:03d}"


def save_run_state(ckpt_dir: str, rnd: int, state: dict,
                   meta: dict | None = None,
                   host: "int | None" = None) -> str:
    """Persist one round's full run state as ``state_NNNNNN.npz`` +
    ``.meta`` (``state_hostNNN_NNNNNN.npz`` when ``host`` is given).
    ``state`` is an arbitrary nesting of dict / list / tuple / arrays /
    scalars / ``ModelBuffer`` — see the module docstring."""
    arrays: dict[str, np.ndarray] = {}
    spec = _encode(state, arrays)
    path = os.path.join(ckpt_dir, f"{_state_prefix(host)}_{rnd:06d}.npz")
    # zero arrays (an all-scalar state) still writes a valid empty npz
    io.save_pytree(path, arrays, meta={"round": rnd, "spec": spec,
                                       **(meta or {})})
    return path


def load_run_state(path: str) -> tuple[dict, dict]:
    """``(state, meta)`` for one state file (raises io.CORRUPT_ERRORS on a
    torn/invalid file — callers resume through ``load_latest_state``)."""
    arrays = io.load_flat(path)
    meta = io.load_meta(path)
    return _decode(meta["spec"], arrays), meta


def load_latest_state(ckpt_dir: str,
                      host: "int | None" = None
                      ) -> "tuple[dict, dict, int] | None":
    """Resume data from the newest LOADABLE state file: ``(state, meta,
    round)``, or ``None`` when the directory holds no state files yet (a
    fresh run).  Unreadable files are skipped newest-first exactly like
    ``io.load_latest``; all-corrupt raises rather than silently
    restarting from scratch."""
    hit = io.latest_loadable(ckpt_dir, _state_prefix(host), load_run_state)
    if hit is None:
        return None
    (state, meta), rnd = hit
    return state, meta, rnd


def load_state_at(ckpt_dir: str, rnd: int,
                  host: "int | None" = None) -> tuple[dict, dict]:
    """``(state, meta)`` for the EXACT round ``rnd`` — the coordinated
    multi-host resume restores the agreed common round, which may be older
    than this host's newest file (a peer died before checkpointing it).
    Raises ``FileNotFoundError`` / ``io.CORRUPT_ERRORS`` rather than
    falling back: the barrier already validated the round exists on every
    host, so a miss here is real corruption."""
    path = os.path.join(ckpt_dir, f"{_state_prefix(host)}_{rnd:06d}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"coordinated resume: {path} missing — host agreed to restore "
            f"round {rnd} but has no state file for it")
    return load_run_state(path)
