"""The client-batched conv route on the paper's CIFAR backbone (resnet8).

Until this route existed, ``executor="vmap"`` on a conv model vmapped the
round body over clients, turning every convolution into a batched-weight
convolution XLA lowers poorly (the ROADMAP caveat).  ResNet bundles now
declare ``client_batched``: the model consumes client-STACKED params
natively — 5-D conv weights dispatch to the fused
``kernels.grouped_conv.client_batched_conv`` (one feature-grouped conv with
a custom VJP) — and the batched executors train the whole cohort as one
stacked program with an unrolled step loop.

This demo trains a small resnet8 cohort on CIFAR-shaped synthetic data
three ways and prints per-round times and the route telemetry:

    PYTHONPATH=src python examples/executor_resnet.py [--rounds 3]

The naive-body round is deliberately included so the speedup the conv
benchmark gates (``BENCH_conv.json``) is reproducible here; expect the
client-batched body to be >10x faster than the naive vmapped-conv body on
a CPU host (see benchmarks/executor_bench.py --conv for the gated measure).
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs.paper import CIFAR10, scaled
from repro.core import algorithms, fl_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=2,
                    help="local steps per client per round")
    ap.add_argument("--skip-naive", action="store_true",
                    help="skip the (slow) naive vmapped-conv baseline")
    args = ap.parse_args()

    task = scaled(CIFAR10, scale=0.01, rounds=args.rounds, local_epochs=1)
    task = dataclasses.replace(
        task, n_clients=max(task.n_clients, args.clients),
        participation=args.clients / max(task.n_clients, args.clients),
        batch_size=args.batch)
    data = fl_loop.make_federated_data(task, alpha=10.0, seed=0, n_test=64)
    print(f"resnet8 width={args.width}, {args.clients} sampled clients, "
          f"{task.image_hw}x{task.image_hw} toy-CIFAR shapes")

    cases = [("sequential", dict(executor="sequential")),
             ("vmap (client-batched)", dict(executor="vmap"))]
    if not args.skip_naive:
        cases.append(("vmap (naive conv body)",
                      dict(executor="vmap", client_batched=False)))

    for label, kw in cases:
        t0 = time.time()
        h = fl_loop.run_federated(task, algorithms.make("fedgkd"), data,
                                  seed=0, width=args.width,
                                  max_batches_per_client=args.steps, **kw)
        dt = time.time() - t0
        per_round = float(np.mean([r.seconds for r in h.records[1:]]
                                  or [h.records[0].seconds]))
        body = h.telemetry.get("round_body", "-")
        print(f"{label:>24}: {per_round:7.2f} s/round (post-compile)  "
              f"total {dt:6.1f}s  round_body={body}  "
              f"final_acc={h.final_acc:.3f}")


if __name__ == "__main__":
    main()
