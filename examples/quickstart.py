"""Quickstart: FedGKD vs FedAvg on non-IID synthetic CIFAR-10 (ResNet-8).

The 60-second tour of the public API: make a task, Dirichlet-partition data
across 20 clients, run both algorithms, compare accuracy curves.

    PYTHONPATH=src python examples/quickstart.py [--rounds 8] [--alpha 0.1]
"""
import argparse

from repro.configs.paper import CIFAR10, scaled
from repro.core import algorithms, fl_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=0.1,
                    help="Dirichlet concentration (smaller = more non-IID)")
    ap.add_argument("--scale", type=float, default=0.05)
    args = ap.parse_args()

    # the paper's CIFAR-10 task, scaled for CPU
    task = scaled(CIFAR10, scale=args.scale, rounds=args.rounds,
                  local_epochs=2)
    data = fl_loop.make_federated_data(task, alpha=args.alpha, seed=0,
                                       n_test=500)
    print(f"{task.n_clients} clients, {data.total_n} train examples, "
          f"α={args.alpha}")
    print("per-client label counts (first 5 clients):")
    print(data.label_matrix[:5])

    results = {}
    for name in ("fedavg", "fedgkd"):
        algo = (algorithms.make("fedgkd", gamma=task.gamma, buffer_m=5)
                if name == "fedgkd" else algorithms.make("fedavg"))
        h = fl_loop.run_federated(task, algo, data, seed=0, verbose=True)
        results[name] = h

    print("\n=== summary ===")
    for name, h in results.items():
        print(f"{name:8s} best={h.best_acc:.4f} final={h.final_acc:.4f} "
              f"local-model acc={h.local_model_acc:.4f}")
    gain = results["fedgkd"].best_acc - results["fedavg"].best_acc
    print(f"FedGKD best-accuracy gain over FedAvg: {gain:+.4f}")


if __name__ == "__main__":
    main()
