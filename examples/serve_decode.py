"""Serving example: batched prefill + greedy KV-cache decode for any
assigned architecture (reduced config on CPU).

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b \
        --prompt-len 32 --gen 24 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b",
                    choices=configs.ALL_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    b = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (b, args.prompt_len), 0, cfg.vocab_size)

    kw = {}
    if cfg.enc_layers:   # enc-dec (audio): encode stubbed frame embeddings
        from repro.models.frontends import synth_embeddings
        enc_emb = synth_embeddings(jax.random.PRNGKey(2), b, 16, cfg.d_model)
        kw["enc_out"] = transformer.encode(params, cfg, enc_emb)

    max_len = args.prompt_len + args.gen + 1
    cache = transformer.init_cache(cfg, b, max_len, jnp.float32)

    decode = jax.jit(lambda p, t, c: transformer.decode_step(p, cfg, t, c, **kw))

    # prefill token-by-token (teacher forcing through the cache)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, prompt[:, i:i + 1], cache)
    t_prefill = time.time() - t0

    # greedy generation
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out_tokens.append(tok)
    t_gen = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={args.arch} batch={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_gen:.2f}s "
          f"({args.gen * b / max(t_gen, 1e-9):.1f} tok/s)")
    print("generated ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
