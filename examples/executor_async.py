"""Async straggler-aware rounds vs the synchronous barrier.

``run_federated(..., executor="async")`` runs buffered-asynchronous rounds
on a simulated heterogeneous system (``repro.core.systemsim``): every
client gets a seeded compute speed, the server aggregates the B earliest
completions with staleness-aware weights, and stale arrivals can be
absorbed into the FedGKD teacher buffer instead of discarded.  This
example puts a 4x straggler tail under 20% of the clients and compares
simulated wall-clock to a fixed accuracy against the synchronous vmap
executor (whose every round waits for the slowest sampled client):

    PYTHONPATH=src python examples/executor_async.py [--rounds 12]
"""
import argparse

from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.core.executor import AsyncExecutor
from repro.core.systemsim import SpeedProfile, SystemSim, derive_rng
from repro.data.pipeline import num_batches


def sync_sim_clock(history, sim: SystemSim, work) -> list[float]:
    """Cumulative synchronous wall-clock: each round ends when the slowest
    sampled client finishes (the barrier the async path removes)."""
    out, t = [], 0.0
    for rec in history.records:
        t += max(sim.duration(k, work[k]) for k in rec.sampled)
        out.append(t)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--buffer", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    profile = SpeedProfile(kind="straggler", straggler_frac=0.2,
                           straggler_slowdown=4.0)
    data = fl_loop.make_federated_data(TOY, alpha=args.alpha, seed=0,
                                       n_test=400)
    work = [num_batches(c.n, TOY.batch_size, TOY.local_epochs)
            for c in data.clients]

    hs = fl_loop.run_federated(TOY, algorithms.make("fedgkd", buffer_m=3),
                               data, rounds=args.rounds, seed=args.seed,
                               executor="vmap")
    sim = SystemSim(data.n_clients, profile, rng=derive_rng(args.seed))
    sync_clock = sync_sim_clock(hs, sim, work)

    ha = fl_loop.run_federated(
        TOY, algorithms.make("fedgkd", buffer_m=3), data,
        rounds=3 * args.rounds, seed=args.seed,
        executor=AsyncExecutor(buffer_size=args.buffer, staleness="fedgkd",
                               profile=profile))

    target = hs.records[-1].test_acc
    print(f"\nsync  ({args.rounds} rounds): acc={target:.4f} at simulated "
          f"t={sync_clock[-1]:.0f}")
    hit = next((r for r in ha.records if r.test_acc >= target), None)
    if hit is None:
        print(f"async ({len(ha.records)} aggregations): best "
              f"acc={ha.best_acc:.4f} — target not reached, raise --rounds")
    else:
        print(f"async (B={args.buffer}): acc={hit.test_acc:.4f} at simulated "
              f"t={hit.sim_time:.0f}  "
              f"({hit.sim_time / sync_clock[-1]:.2f}x the sync clock)")
    tele = ha.telemetry
    print(f"staleness: mean={tele['mean_staleness']:.2f} "
          f"max={tele['max_staleness']:.0f}; "
          f"{tele['stale_absorbed']} stale updates absorbed into the "
          f"teacher buffer")


if __name__ == "__main__":
    main()
