"""Selecting a ClientExecutor: batched (vmap) vs sequential client rounds.

``run_federated`` takes ``executor=`` — "sequential", "vmap", "shard_map"
or "auto" (default).  The vmap executor stacks the sampled clients' padded
batches and trains the whole cohort in ONE jitted XLA call, so per-round
wall-clock stops scaling linearly with participation while producing the
same numbers as the sequential reference (same batch draws, masked padding).

    PYTHONPATH=src python examples/executor_vmap.py [--rounds 5]
"""
import argparse
import time


from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=1.0)
    args = ap.parse_args()

    data = fl_loop.make_federated_data(TOY, alpha=args.alpha, seed=0,
                                       n_test=400)
    print(f"{TOY.n_clients} clients, "
          f"{int(TOY.participation * TOY.n_clients)} sampled per round")

    results = {}
    for executor in ("sequential", "vmap"):
        algo = algorithms.make("fedgkd", gamma=TOY.gamma, buffer_m=3)
        t0 = time.time()
        h = fl_loop.run_federated(TOY, algo, data, rounds=args.rounds,
                                  seed=0, executor=executor)
        results[executor] = (h, time.time() - t0)
        print(f"{executor:>10}: final_acc={h.final_acc:.4f} "
              f"({results[executor][1]:.1f}s total)")

    hs, ts = results["sequential"]
    hv, tv = results["vmap"]
    drift = max(abs(a - b) for a, b in zip(hs.accs(), hv.accs()))
    print(f"\nmax per-round accuracy drift: {drift:.2e} (same numbers)")
    print(f"wall-clock: sequential {ts:.1f}s vs vmap {tv:.1f}s")


if __name__ == "__main__":
    main()
