"""End-to-end driver (deliverable (b)): federated training of a ~100M-param
causal LM with FedGKD for a few hundred local steps.

Builds a 12-layer/d=640 GQA transformer (≈100M params with its 32k vocab),
splits a synthetic token stream across 4 non-IID clients (distinct Markov
sources), and runs FedGKD rounds; each round is 4 clients × E local steps.

    PYTHONPATH=src python examples/train_federated_lm.py            # full
    PYTHONPATH=src python examples/train_federated_lm.py --tiny     # smoke
"""
import argparse

from repro.launch import train as fl_train
from repro.models.config import ModelConfig


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="fedlm-100m", family="dense", n_layers=12, d_model=640,
        n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=32_000, head_dim=64,
        norm="rms", act="swiglu", tie_embeddings=True,
        param_dtype="float32", activation_dtype="float32")


def lm_tiny() -> ModelConfig:
    return lm_100m().replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                             d_ff=512, vocab_size=1_000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--steps-per-round", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    n_params = cfg.param_count()
    total_steps = args.rounds * args.clients * args.steps_per_round
    print(f"model: {cfg.name} ~{n_params/1e6:.0f}M params; "
          f"{args.rounds} rounds × {args.clients} clients × "
          f"{args.steps_per_round} steps = {total_steps} local steps")

    out = fl_train.run_serial(
        cfg, rounds=args.rounds, n_clients=args.clients,
        batches_per_round=args.steps_per_round, batch=args.batch,
        seq=args.seq, algo="fedgkd", gamma=0.2, buffer_m=3,
        lr=0.02 if args.tiny else 0.01)
    print("perplexity trajectory:",
          [f"{h['ppl']:.1f}" for h in out["history"]])


if __name__ == "__main__":
    main()
