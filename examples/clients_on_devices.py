"""Clients-on-devices example: one FL round as a single shard_map program.

Every host device hosts one client; local epochs run with zero cross-client
traffic and the server aggregation is one weighted psum — the TPU-pod
mapping of the paper's MPI setup (DESIGN.md §4).  Run with several CPU
devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/clients_on_devices.py
"""
import jax

from repro.configs import get_smoke_config
from repro.launch.train import run_sharded


def main():
    n = len(jax.devices())
    print(f"{n} devices -> {n} federated clients (1 client/device)")
    cfg = get_smoke_config("phi4-mini-3.8b")
    out = run_sharded(cfg, rounds=3, batches_per_round=4, batch=4, seq=64,
                      algo="fedgkd", gamma=0.2, buffer_m=3, lr=0.05)
    print("ppl trajectory:", [f"{h['ppl']:.1f}" for h in out["history"]])


if __name__ == "__main__":
    main()
