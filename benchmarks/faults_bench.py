"""Accuracy-vs-fault-rate and round-completion-overhead benchmark.

Sweeps the deterministic fault injector (``repro.core.systemsim``) over a
grid of crash rates (default 0 / 10 / 20%, each with 5% update corruption)
on the ``toy`` preset and measures, per algorithm:

  * ``acc_drop_at_20pct_crash`` — final-round accuracy lost at the
    heaviest cell versus the fault-free run (LOWER is better; the
    PR-7 acceptance criterion caps it at 0.02);
  * ``overhead_ratio`` — client trainings dispatched per completed round
    under faults, relative to the fault-free cohort (LOWER is better).
    Retries re-dispatch failed clients, so this is
    ``1 + redispatches / (rounds * cohort)`` — a DETERMINISTIC function
    of the seed, immune to CI runner speed, unlike wall-clock (which is
    still recorded informationally as ``wall_ratio``).

Writes ``BENCH_faults.json`` at the repo root — the artifact
``benchmarks/compare_bench.py`` gates the nightly ``faults-bench`` job on
(both metrics lower-is-better).  The in-run acceptance gate mirrors
``tests/test_faults.py``:

    PYTHONPATH=src python benchmarks/faults_bench.py               # default
    PYTHONPATH=src python benchmarks/faults_bench.py --algos fedgkd \
        --rounds 12 --crash-grid 0 0.1 0.2 0.3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax

from repro.configs.paper import PAPER_TASKS
from repro.core import algorithms, fl_loop
from repro.core.systemsim import FaultProfile

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run(algo_name, task, data, args, crash):
    mk = algorithms.make(algo_name, **(
        {"buffer_m": args.buffer_m} if algo_name.startswith("fedgkd") else {}))
    faults = None
    if crash is not None:
        faults = FaultProfile(crash_prob=crash, corrupt_prob=args.corrupt)
    t0 = time.perf_counter()
    h = fl_loop.run_federated(task, mk, data, rounds=args.rounds,
                              seed=args.seed, executor="vmap", faults=faults)
    return h, time.perf_counter() - t0


def bench_algo(algo_name: str, task, data, n_sample: int, args) -> dict:
    # fault-free reference (also warms the jit caches the sweep reuses)
    clean, wall_clean = _run(algo_name, task, data, args, None)
    clean_acc = clean.records[-1].test_acc

    cells = []
    for crash in args.crash_grid:
        h, wall = _run(algo_name, task, data, args, crash)
        ftel = h.telemetry["faults"]
        dispatches = args.rounds * n_sample + ftel["redispatches"]
        cells.append({
            "crash_prob": crash, "corrupt_prob": args.corrupt,
            "final_acc": round(h.records[-1].test_acc, 4),
            "acc_drop": round(clean_acc - h.records[-1].test_acc, 4),
            "rounds_completed": len(h.records),
            "skipped_rounds": ftel["skipped_rounds"],
            "crashes": ftel["crashes"],
            "corrupt_injected": ftel["corrupt_injected"],
            "rejected": (ftel["rejected_nonfinite"] + ftel["rejected_norm"]),
            "retries": ftel["retries"],
            "redispatches": ftel["redispatches"],
            "overhead_ratio": round(dispatches / (args.rounds * n_sample), 4),
            "wall_ratio": round(wall / wall_clean, 3),
        })

    heavy = max(cells, key=lambda c: c["crash_prob"])
    return {"algo": algo_name, "executor": "vmap",
            "epochs": task.local_epochs, "precompute": True,
            "faults": f"crash{int(100 * heavy['crash_prob'])}"
                      f"+corrupt{int(100 * args.corrupt)}",
            "clean_acc": round(clean_acc, 4),
            "acc_drop_at_20pct_crash": heavy["acc_drop"],
            "overhead_ratio": heavy["overhead_ratio"],
            "wall_ratio": heavy["wall_ratio"],
            "sweep": cells}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="toy", choices=sorted(PAPER_TASKS))
    ap.add_argument("--algos", nargs="+", default=["fedavg", "fedgkd"])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--crash-grid", nargs="+", type=float,
                    default=[0.0, 0.1, 0.2], dest="crash_grid")
    ap.add_argument("--corrupt", type=float, default=0.05)
    ap.add_argument("--buffer-m", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--max-acc-drop", type=float, default=0.02,
                    dest="max_acc_drop",
                    help="fail if the heaviest cell loses more than this "
                         "much accuracy vs fault-free (the acceptance "
                         "criterion); negative disables the gate")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_faults.json"))
    args = ap.parse_args(argv)

    task = PAPER_TASKS[args.task]
    data = fl_loop.make_federated_data(task, alpha=args.alpha, seed=0,
                                       n_test=400)
    n_sample = max(1, int(round(task.participation * data.n_clients)))

    cases = []
    for algo_name in args.algos:
        row = bench_algo(algo_name, task, data, n_sample, args)
        cases.append(row)
        print(f"{algo_name:>12}: clean acc {row['clean_acc']:.4f}; at "
              f"{row['faults']}: drop {row['acc_drop_at_20pct_crash']:+.4f}, "
              f"dispatch overhead {row['overhead_ratio']:.3f}x "
              f"(wall {row['wall_ratio']:.2f}x)")

    payload = {"task": args.task, "devices": len(jax.devices()),
               "backend": jax.default_backend(), "clients": n_sample,
               "width": 16, "corrupt": args.corrupt,
               "crash_grid": args.crash_grid, "cases": cases}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")

    if args.max_acc_drop >= 0:
        bad = [c for c in cases
               if c["acc_drop_at_20pct_crash"] > args.max_acc_drop
               or any(cell["skipped_rounds"] > 0
                      or cell["rounds_completed"] != args.rounds
                      for cell in c["sweep"])]
        if bad:
            print(f"FAIL: {len(bad)} case(s) violated the <= "
                  f"{args.max_acc_drop:.2f} accuracy-drop / full-completion "
                  f"criterion: {[c['algo'] for c in bad]}")
            return 1
        print(f"all cases completed every round within "
              f"{args.max_acc_drop:.2f} of fault-free accuracy")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
