"""Paper Tables 7-8: buffer-length ablation (M ∈ {1, 3, 5, 7}) for FedGKD
and FedGKD-VOTE."""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, run_methods
from repro.configs.paper import CIFAR10


def run(preset: str = "fast"):
    cfgs = {
        "fast": dict(scale=0.02, rounds=3, ms=[1, 3], methods=["fedgkd"]),
        "medium": dict(scale=0.05, rounds=10, ms=[1, 3, 5, 7],
                       methods=["fedgkd", "fedgkd-vote"]),
        "full": dict(scale=0.1, rounds=20, ms=[1, 3, 5, 7],
                     methods=["fedgkd", "fedgkd-vote"]),
    }[preset]
    rows = []
    for m in cfgs["ms"]:
        out = run_methods(CIFAR10, cfgs["methods"], [0.1], trials=1,
                          scale=cfgs["scale"], rounds=cfgs["rounds"],
                          local_epochs=2, buffer_m=m)
        for r in out:
            r["buffer_m"] = m
        rows += out
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="medium",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    rows = run(args.preset)
    print(csv_rows(rows, ["method", "buffer_m", "best_mean", "final_mean"]))


if __name__ == "__main__":
    main()
