"""Paper Table 5: participation-ratio sweep (C ∈ {0.1..0.4}, α=0.5)."""
from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import csv_rows, run_methods
from repro.configs.paper import CIFAR10


def run(preset: str = "fast"):
    cfgs = {
        "fast": dict(scale=0.02, rounds=3, trials=1, cs=[0.1, 0.4],
                     methods=["fedavg", "fedgkd"]),
        "medium": dict(scale=0.05, rounds=8, trials=1,
                       cs=[0.1, 0.2, 0.3, 0.4],
                       methods=["fedavg", "fedprox", "fedgkd", "fedgkd-vote"]),
        "full": dict(scale=0.1, rounds=15, trials=3, cs=[0.1, 0.2, 0.3, 0.4],
                     methods=["fedavg", "fedprox", "moon", "feddistill+",
                              "fedgen", "fedgkd", "fedgkd-vote", "fedgkd+"]),
    }[preset]
    rows = []
    for c in cfgs["cs"]:
        task = dataclasses.replace(CIFAR10, participation=c)
        out = run_methods(task, cfgs["methods"], [0.5], trials=cfgs["trials"],
                          scale=cfgs["scale"], rounds=cfgs["rounds"],
                          local_epochs=2)
        for r in out:
            r["participation"] = c
        rows += out
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="medium",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    rows = run(args.preset)
    print(csv_rows(rows, ["method", "participation", "best_mean", "final_mean",
                          "seconds"]))


if __name__ == "__main__":
    main()
