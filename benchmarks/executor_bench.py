"""Executor benchmark: sequential vs vmap (vs shard_map) per-round time.

Measures ONLY the client-execution stage (``ClientExecutor.run_round``) so
the comparison isolates the executor pipeline: with the sequential executor,
round time scales linearly with the number of sampled clients; with the vmap
executor the whole cohort is one jitted XLA call.  Each (algo, executor)
case additionally runs with the round-level teacher-precompute stage ON and
OFF (where the algorithm has one), so the KD-precompute speedup is tracked
round over round.

Writes ``BENCH_executor.json`` at the repo root — the perf-trajectory
artifact future PRs diff against (``benchmarks/compare_bench.py`` gates the
nightly CI job on it):

    PYTHONPATH=src python benchmarks/executor_bench.py            # fast preset
    PYTHONPATH=src python benchmarks/executor_bench.py --clients 16 --rounds 5
    # the forced-multi-device case: shard_map on an 8-device host mesh
    PYTHONPATH=src python benchmarks/executor_bench.py \
        --host-devices 8 --with-shard-map

``--conv`` switches to the CLIENT-BATCHED CONV case (resnet8 on toy-CIFAR
shapes, the paper's CIFAR backbone): the vmap executor runs the cohort
twice per timed round — once through the client-batched grouped-conv body
(``kernels.grouped_conv`` + unrolled steps) and once through the naive
vmapped-conv body (``client_batched=False``, the historical round fn) —
interleaved, so the paired ``speedup_vs_naive_vmap`` ratio is drift-robust.
Writes ``BENCH_conv.json``; the nightly ``conv-bench`` job gates it via
``compare_bench.py``:

    PYTHONPATH=src python benchmarks/executor_bench.py --conv
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time

import jax
import numpy as np

from repro.configs.paper import PAPER_TASKS, scaled
from repro.core import algorithms, executor as executor_lib, fl_loop
from repro.optim import adam, sgd

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def bench_executor(name: str, ctxs, data, n_sample: int, seed: int,
                   global_params, payloads, states, *, rounds: int) -> list:
    """Time ``run_round`` only, for one executor across several round
    contexts (precompute on/off) with INTERLEAVED timed rounds — host-load
    drift over the run hits every variant equally, so the recorded
    speedups are drift-robust.  ``payloads`` is one broadcast payload per
    timed round: KD algorithms rotate one teacher per round, so the
    cross-round logit cache is measured at its honest steady state, never
    at an all-hits fixed-payload best case."""
    if name == "shard_map":
        # strict: benchmark the REAL mesh route or die — never time the
        # vmap fallback under a shard_map label (main() refuses the case
        # on a single-device host before it gets here)
        exec_ = executor_lib.ShardMapExecutor(strict=True)
    else:
        exec_ = executor_lib.get_executor(name, ctxs[0].algo, n_sample)
    rng = np.random.default_rng(seed)
    sampled = rng.choice(data.n_clients, size=n_sample, replace=False)
    cdata = [data.clients[int(k)] for k in sampled]
    cstates = [states[int(k)] for k in sampled]
    cids = [int(k) for k in sampled]

    times: list[list[float]] = [[] for _ in ctxs]
    for ctx in ctxs:    # warmup: compile outside the timed region
        res = exec_.run_round(ctx, global_params, payloads[0], cstates,
                              cdata, rng, client_ids=cids)
        jax.block_until_ready(res.uploads[-1]["params"])
    for t in range(rounds):
        payload = payloads[min(t + 1, len(payloads) - 1)]
        for i, ctx in enumerate(ctxs):
            t0 = time.perf_counter()
            res = exec_.run_round(ctx, global_params, payload, cstates,
                                  cdata, rng, client_ids=cids)
            jax.block_until_ready(res.uploads[-1]["params"])
            times[i].append(time.perf_counter() - t0)
    return [{"executor": name, "median_s": float(np.median(ts)),
             "min_s": float(np.min(ts)), "rounds": rounds,
             "times_s": [round(t, 5) for t in ts]} for ts in times]


def _make_algo(name: str) -> algorithms.Algorithm:
    if name == "fedgkd-vote":
        return algorithms.make(name, buffer_m=5)       # the M=5 tracking case
    return algorithms.make(name)


def bench_algo(algo_name: str, task, data, args) -> list[dict]:
    """All (executor, precompute, epochs) cases for one algorithm."""
    algo = _make_algo(algo_name)
    from repro.core.modelzoo import make_model
    model = make_model(task, projection_head=algo.needs_projection_head,
                       width=args.width)
    global_params = model.init(jax.random.PRNGKey(1))
    server = algo.init_server(global_params, model, task.num_classes)
    buffer = server.get("buffer")
    if buffer is not None:
        # fill the buffer so the teacher ensemble is real, not padding
        for m in range(buffer.size - 1):
            buffer.push(jax.tree_util.tree_map(
                lambda p: p * (1.0 + 0.01 * (m + 1)), global_params))
        if "val_losses" in server:
            server["val_losses"] = [0.1 * (m + 1) for m in range(buffer.size)]
    # one payload per timed round (+ warmup): teachers rotate like a real run
    payloads = []
    for t in range(args.rounds + 1):
        if buffer is not None and t > 0:
            buffer.push(jax.tree_util.tree_map(
                lambda p: p * (1.0 + 0.001 * t), global_params))
        payloads.append(algo.round_payload(server, jax.random.PRNGKey(2 + t)))
    opt = (adam(weight_decay=task.weight_decay) if task.optimizer == "adam"
           else sgd(momentum=task.momentum, weight_decay=task.weight_decay))
    states = {k: algo.init_client_state(k, global_params)
              for k in range(data.n_clients)}

    names = ["sequential", "vmap"]
    if args.with_shard_map:
        names.append("shard_map")
    has_pre = (type(algo).precompute_aux
               is not algorithms.Algorithm.precompute_aux)

    rows = []
    for epochs in args.epochs_list:
        seq_base: dict = {}             # per-variant sequential reference
        for name in names:
            variants = [True, False] if has_pre else [True]
            ctxs = [executor_lib.RoundContext(
                        algo=algo, model=model, opt=opt, lr=task.lr,
                        batch_size=task.batch_size, epochs=epochs,
                        max_batches=args.max_batches, precompute=pre)
                    for pre in variants]
            case_rows = bench_executor(name, ctxs, data, args.clients, 0,
                                       global_params, payloads, states,
                                       rounds=args.rounds)
            for r, pre in zip(case_rows, variants):
                r.update(algo=algo_name, epochs=epochs,
                         precompute=bool(pre and has_pre))
            if has_pre:
                # the tentpole criterion: precompute vs the PR-1 inline
                # (no-aux) baseline at the same executor.  The rounds are
                # interleaved, so the median of PER-ROUND ratios is immune
                # to both load drift (hits the pair equally) and isolated
                # spikes (trimmed by the median).
                pair = np.asarray(case_rows[1]["times_s"]) / np.asarray(
                    case_rows[0]["times_s"])
                case_rows[0]["speedup_vs_no_precompute"] = float(
                    np.median(pair))
            if name == "sequential":
                seq_base = {r["precompute"]: r["min_s"] for r in case_rows}
            for r in case_rows:
                # like-for-like: each variant against the SAME-variant
                # sequential run (a pre-off row never mixes with the
                # pre-on sequential baseline)
                base = seq_base.get(r["precompute"])
                if base:
                    r["speedup_vs_sequential"] = base / r["min_s"]
            rows.extend(case_rows)
    return rows


def bench_conv(args) -> int:
    """The client-batched grouped-conv case: resnet8, 8-client cohort.

    Rows: sequential reference, vmap with the NAIVE vmapped-conv round
    body, vmap with the CLIENT-BATCHED body (grouped-conv kernels).  The
    acceptance metric is ``speedup_vs_naive_vmap`` — median per-round
    paired ratio of interleaved naive/batched rounds (same executor, same
    cohort, same batch draws).
    """
    from repro.core.modelzoo import make_model

    # toy-CIFAR sizing: full CIFAR is pointless for a round-time measure —
    # ~2 local steps per client at the paper's 32x32x3 shapes is the
    # executor-bound regime the comparison targets (main() defaults
    # --scale to 0.01 under --conv)
    task = scaled(PAPER_TASKS["cifar10"], scale=args.scale, rounds=1,
                  local_epochs=1)
    task = dataclasses.replace(
        task, n_clients=max(task.n_clients, args.clients),
        participation=args.clients / max(task.n_clients, args.clients),
        batch_size=args.conv_batch)
    data = fl_loop.make_federated_data(task, alpha=args.alpha, seed=0,
                                       n_test=32)

    all_rows = []
    for algo_name in args.conv_algos:
        algo = algorithms.make(algo_name)
        model = make_model(task, width=args.conv_width)
        global_params = model.init(jax.random.PRNGKey(1))
        server = algo.init_server(global_params, model, task.num_classes)
        payloads = [algo.round_payload(server, jax.random.PRNGKey(2 + t))
                    for t in range(args.rounds + 1)]
        opt = sgd(momentum=task.momentum, weight_decay=task.weight_decay)
        states = {k: algo.init_client_state(k, global_params)
                  for k in range(data.n_clients)}

        def mk_ctx(client_batched):
            return executor_lib.RoundContext(
                algo=algo, model=model, opt=opt, lr=task.lr,
                batch_size=task.batch_size, epochs=1,
                max_batches=args.conv_steps, client_batched=client_batched)

        rows = bench_executor("sequential", [mk_ctx("auto")], data,
                              args.clients, 0, global_params, payloads,
                              states, rounds=args.rounds)
        # interleaved pair: [client-batched body, naive vmapped-conv body]
        pair = bench_executor("vmap", [mk_ctx("auto"), mk_ctx(False)], data,
                              args.clients, 0, global_params, payloads,
                              states, rounds=args.rounds)
        batched_row, naive_row = pair
        batched_row["conv_route"] = "client_batched"
        naive_row["conv_route"] = "naive"
        ratio = np.asarray(naive_row["times_s"]) / np.asarray(
            batched_row["times_s"])
        batched_row["speedup_vs_naive_vmap"] = float(np.median(ratio))
        rows.extend(pair)
        seq_min = rows[0]["min_s"]
        for r in rows:
            r.update(algo=algo_name, epochs=1, precompute=False,
                     model="resnet8")
            r["speedup_vs_sequential"] = seq_min / r["min_s"]
        all_rows.extend(rows)

        print(f"\n{algo_name} resnet8 conv case: {args.clients} clients, "
              f"width={args.conv_width}, batch={args.conv_batch}, "
              f"steps={args.conv_steps}")
        for r in rows:
            route = r.get("conv_route", "-")
            print(f"  {r['executor']:<10} {route:<15} "
                  f"{r['median_s']:>9.3f} s/round  "
                  f"vs naive-vmap "
                  f"{r.get('speedup_vs_naive_vmap', float('nan')):>6.2f}x")

    payload = {
        "bench": "conv", "task": "cifar10", "model": "resnet8",
        "clients": args.clients, "width": args.conv_width,
        "batch_size": args.conv_batch, "steps": args.conv_steps,
        "alpha": args.alpha, "timing_rounds": args.rounds,
        "backend": jax.default_backend(), "devices": len(jax.devices()),
        "notes": (
            "speedup_vs_naive_vmap = median per-round paired ratio "
            "(interleaved rounds, same vmap executor) of the historical "
            "vmapped-conv round body over the client-batched grouped-conv "
            "body (kernels/grouped_conv custom-VJP formulas + unrolled "
            "step loop).  The acceptance floor from the issue is 1.3x; "
            "the gate in nightly.yml fails on a >20% regression of the "
            "committed ratio."),
        "cases": all_rows,
    }
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {out}")
    floor = min(r["speedup_vs_naive_vmap"] for r in all_rows
                if "speedup_vs_naive_vmap" in r)
    print(f"minimum speedup_vs_naive_vmap across cases: {floor:.2f}x")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="toy", choices=sorted(PAPER_TASKS),
                    help="'toy' (MLP, the fast preset) or a paper task")
    ap.add_argument("--clients", type=int, default=8,
                    help="sampled clients per round (>=8 for the criterion)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="timed rounds per case (default 8; 3 under --conv "
                         "— the naive vmapped-conv rounds are slow)")
    ap.add_argument("--scale", type=float, default=None,
                    help="dataset scale (default 1.0; paper tasks need "
                         "~0.02, --conv defaults to 0.01)")
    ap.add_argument("--epochs-list", type=int, nargs="+", default=[2],
                    dest="epochs_list", help="local-epoch settings to sweep")
    ap.add_argument("--max-batches", type=int, default=None)
    ap.add_argument("--width", type=int, default=32,
                    help="MLP width knob; 32 puts the toy task in the "
                         "compute-bound regime the executor comparison "
                         "targets (8 is dispatch-overhead-bound)")
    ap.add_argument("--algos", nargs="+",
                    default=["fedavg", "fedgkd", "fedgkd-vote"],
                    help="algorithms to benchmark (fedgkd-vote runs M=5)")
    ap.add_argument("--alpha", type=float, default=10.0,
                    help="Dirichlet concentration; small alpha => ragged "
                         "client sizes => more padding waste on the vmap path")
    ap.add_argument("--with-shard-map", action="store_true")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force this many XLA host-platform devices (the "
                         "multi-device shard_map case on a CPU box); must "
                         "run before jax initializes a backend")
    ap.add_argument("--conv", action="store_true",
                    help="run the client-batched conv case (resnet8 on "
                         "toy-CIFAR shapes) and write BENCH_conv.json")
    ap.add_argument("--conv-width", type=int, default=16,
                    help="resnet8 width for --conv (16 = the paper's scale)")
    ap.add_argument("--conv-batch", type=int, default=16, dest="conv_batch")
    ap.add_argument("--conv-steps", type=int, default=2, dest="conv_steps",
                    help="local steps per client per round for --conv")
    ap.add_argument("--conv-algos", nargs="+", default=["fedavg"],
                    dest="conv_algos")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.rounds is None:
        args.rounds = 3 if args.conv else 8
    if args.scale is None:
        args.scale = 0.01 if args.conv else 1.0
    if args.out is None:
        args.out = str(REPO_ROOT / ("BENCH_conv.json" if args.conv
                                    else "BENCH_executor.json"))

    if args.host_devices:
        # XLA reads the flag at first backend init, which nothing in this
        # module triggers at import time — but verify rather than hope
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}"
        ).strip()
        if len(jax.devices()) != args.host_devices:
            sys.exit(f"--host-devices {args.host_devices} requested but jax "
                     f"already initialized {len(jax.devices())} device(s); "
                     f"set XLA_FLAGS in the environment instead")
    if args.with_shard_map and len(jax.devices()) == 1:
        sys.exit("--with-shard-map on a single device would only measure "
                 "the vmap fallback under a shard_map label; pass "
                 "--host-devices N (or set XLA_FLAGS) for a real mesh")
    if args.conv:
        return bench_conv(args)

    task = scaled(PAPER_TASKS[args.task], scale=args.scale, rounds=1,
                  local_epochs=max(args.epochs_list))
    task = dataclasses.replace(
        task, n_clients=max(task.n_clients, args.clients),
        participation=args.clients / max(task.n_clients, args.clients))
    data = fl_loop.make_federated_data(task, alpha=args.alpha, seed=0,
                                       n_test=64)

    all_rows = []
    for algo_name in args.algos:
        rows = bench_algo(algo_name, task, data, args)
        all_rows.extend(rows)
        print(f"\n{algo_name} on {task.name}, {args.clients} sampled "
              f"clients, width={args.width}")
        print(f"{'executor':<12} {'epochs':>6} {'pre':>5} "
              f"{'median s/round':>15} {'vs seq':>8} {'vs no-pre':>10}")
        for r in rows:
            print(f"{r['executor']:<12} {r['epochs']:>6} "
                  f"{str(r['precompute']):>5} {r['median_s']:>15.4f} "
                  f"{r.get('speedup_vs_sequential', float('nan')):>7.2f}x "
                  f"{r.get('speedup_vs_no_precompute', float('nan')):>9.2f}x")

    payload = {
        "bench": "executor", "task": task.name, "clients": args.clients,
        "width": args.width, "alpha": args.alpha,
        "timing_rounds": args.rounds, "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "notes": (
            "speedup_vs_no_precompute = median per-round paired ratio "
            "(interleaved rounds) of the inline (PR-1) loss path over the "
            "precompute pipeline at the same executor; "
            "speedup_vs_sequential compares like-for-like "
            "precompute variants. On CPU the student fwd+bwd dominates the "
            "round (~3 forward-equivalents/epoch), so teacher hoisting "
            "caps near (3E+M*E)/(3E+M) — the issue's epochs=2 targets "
            "(fedgkd 1.3x, vote 2x) need a TPU-class accelerator where "
            "per-step teacher loops and softmax HBM traffic cost more; "
            "see ROADMAP."),
        "cases": all_rows,
    }
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
