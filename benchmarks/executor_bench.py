"""Executor benchmark: sequential vs vmap (vs shard_map) per-round time.

Measures ONLY the client-execution stage (``ClientExecutor.run_round``) so
the comparison isolates what the tentpole changed: with the sequential
executor, round time scales linearly with the number of sampled clients;
with the vmap executor the whole cohort is one jitted XLA call.

    PYTHONPATH=src python benchmarks/executor_bench.py            # fast preset
    PYTHONPATH=src python benchmarks/executor_bench.py --clients 16 --rounds 5
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.paper import PAPER_TASKS, scaled
from repro.core import algorithms, executor as executor_lib, fl_loop
from repro.optim import adam, sgd


def bench_executor(name: str, ctx, data, n_sample: int, seed: int,
                   global_params, payload, states, *, rounds: int) -> dict:
    exec_ = executor_lib.get_executor(name, ctx.algo, n_sample)
    rng = np.random.default_rng(seed)
    sampled = rng.choice(data.n_clients, size=n_sample, replace=False)
    cdata = [data.clients[int(k)] for k in sampled]
    cstates = [states[int(k)] for k in sampled]

    # warmup: compile outside the timed region
    res = exec_.run_round(ctx, global_params, payload, cstates, cdata, rng)
    jax.block_until_ready(res.uploads[-1]["params"])

    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        res = exec_.run_round(ctx, global_params, payload, cstates, cdata, rng)
        jax.block_until_ready(res.uploads[-1]["params"])
        times.append(time.perf_counter() - t0)
    return {"executor": name, "median_s": float(np.median(times)),
            "min_s": float(np.min(times)), "rounds": rounds}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="toy", choices=sorted(PAPER_TASKS),
                    help="'toy' (MLP, the fast preset) or a paper task")
    ap.add_argument("--clients", type=int, default=8,
                    help="sampled clients per round (>=8 for the criterion)")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="dataset scale (paper tasks need ~0.02)")
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--max-batches", type=int, default=None)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--algo", default="fedgkd")
    ap.add_argument("--alpha", type=float, default=10.0,
                    help="Dirichlet concentration; small alpha => ragged "
                         "client sizes => more padding waste on the vmap path")
    ap.add_argument("--with-shard-map", action="store_true")
    args = ap.parse_args(argv)

    task = scaled(PAPER_TASKS[args.task], scale=args.scale, rounds=1,
                  local_epochs=args.local_epochs)
    task = dataclasses.replace(
        task, n_clients=max(task.n_clients, args.clients),
        participation=args.clients / max(task.n_clients, args.clients))
    data = fl_loop.make_federated_data(task, alpha=args.alpha, seed=0,
                                       n_test=64)
    algo = algorithms.make(args.algo)

    from repro.core.modelzoo import make_model
    model = make_model(task, projection_head=algo.needs_projection_head,
                       width=args.width)
    global_params = model.init(jax.random.PRNGKey(1))
    server = algo.init_server(global_params, model, task.num_classes)
    payload = algo.round_payload(server, jax.random.PRNGKey(2))
    opt = (adam(weight_decay=task.weight_decay) if task.optimizer == "adam"
           else sgd(momentum=task.momentum, weight_decay=task.weight_decay))
    ctx = executor_lib.RoundContext(
        algo=algo, model=model, opt=opt, lr=task.lr,
        batch_size=task.batch_size, epochs=task.local_epochs,
        max_batches=args.max_batches)
    states = {k: algo.init_client_state(k, global_params)
              for k in range(data.n_clients)}

    names = ["sequential", "vmap"]
    if args.with_shard_map:
        names.append("shard_map")
    rows = [bench_executor(n, ctx, data, args.clients, 0, global_params,
                           payload, states, rounds=args.rounds)
            for n in names]

    print(f"\n{args.algo} on {task.name}, {args.clients} sampled clients, "
          f"{args.local_epochs} local epochs, width={args.width}")
    print(f"{'executor':<12} {'median s/round':>15} {'min s/round':>13}")
    for r in rows:
        print(f"{r['executor']:<12} {r['median_s']:>15.4f} {r['min_s']:>13.4f}")
    base = rows[0]["median_s"]
    for r in rows[1:]:
        print(f"speedup {r['executor']} vs sequential: "
              f"{base / r['median_s']:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
