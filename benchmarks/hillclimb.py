"""§Perf hillclimb driver: run the iteration ladder for the three selected
(arch × shape) pairs and dump roofline terms per variant.

Pairs (selection rationale in EXPERIMENTS.md §Perf):
  A mixtral-8x7b   × train_4k    worst useful-flops ratio (0.04)
  B deepseek-v3-671b × train_4k  worst memory term + paper-representative
  C llava-next-34b × prefill_32k most collective-bound

    PYTHONPATH=src python -m benchmarks.hillclimb --pair A --out results/perf_A.jsonl
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json


def variants_for(pair: str):
    from repro.configs import get_config
    if pair == "A":
        arch, shape = "mixtral-8x7b", "train_4k"
        moe = get_config(arch).moe
        con = moe._replace(dp_axis="data", ep_axis="model")
        return arch, shape, [
            ("A0-fedavg-step", dict(kd_mode="none")),
            ("A1-paper-faithful", dict(kd_mode="teacher")),
            ("A2-moe-shard-constraints", dict(kd_mode="teacher",
                                              extra_cfg={"moe": con})),
            ("A3-+group2048", dict(kd_mode="teacher", extra_cfg={
                "moe": con._replace(group_size=2048), "moe_group_size": 2048})),
            ("A4-+cap1.0", dict(kd_mode="teacher", extra_cfg={
                "moe": con._replace(group_size=2048, capacity_factor=1.0),
                "moe_group_size": 2048})),
            ("A5-beyond-cached-topk", dict(kd_mode="cached_topk", extra_cfg={
                "moe": con._replace(group_size=2048, capacity_factor=1.0),
                "moe_group_size": 2048})),
            ("A6-+sp-attn+sp-residual", dict(kd_mode="cached_topk", extra_cfg={
                "moe": con._replace(group_size=2048, capacity_factor=1.0),
                "moe_group_size": 2048,
                "attn_dp_axis": "data", "attn_sp_axis": "model",
                "residual_dp_axis": "data", "residual_sp_axis": "model"})),
        ]
    if pair == "B":
        arch, shape = "deepseek-v3-671b", "train_4k"
        moe = get_config(arch).moe
        con = moe._replace(dp_axis="data", ep_axis="model")
        return arch, shape, [
            ("B0-fedavg-step", dict(kd_mode="none")),
            ("B1-paper-faithful", dict(kd_mode="teacher")),
            ("B2-moe-shard-constraints", dict(kd_mode="teacher",
                                              extra_cfg={"moe": con})),
            ("B3-+group1024", dict(kd_mode="teacher", extra_cfg={
                "moe": con._replace(group_size=1024), "moe_group_size": 1024})),
            ("B4-beyond-cached-topk", dict(kd_mode="cached_topk", extra_cfg={
                "moe": con._replace(group_size=1024), "moe_group_size": 1024})),
            ("B5-+sp-residual", dict(kd_mode="cached_topk", extra_cfg={
                "moe": con._replace(group_size=1024), "moe_group_size": 1024,
                "residual_dp_axis": "data", "residual_sp_axis": "model"})),
        ]
    if pair == "C":
        arch, shape = "llava-next-34b", "prefill_32k"
        sp = {"attn_dp_axis": "data", "attn_sp_axis": "model"}
        return arch, shape, [
            ("C0-baseline-full-logits", dict(kd_mode="none")),
            ("C1-last-token-logits", dict(kd_mode="none",
                                          prefill_last_only=True)),
            ("C2-seq-parallel-attn", dict(kd_mode="none", extra_cfg=dict(sp))),
            ("C3-sp-attn+last-token", dict(kd_mode="none",
                                           extra_cfg=dict(sp),
                                           prefill_last_only=True)),
            ("C4-+megatron-sp-residual", dict(
                kd_mode="none", prefill_last_only=True,
                extra_cfg=dict(sp, residual_dp_axis="data",
                               residual_sp_axis="model"))),
        ]
    raise ValueError(pair)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=("A", "B", "C"))
    ap.add_argument("--out", default=None)
    ap.add_argument("--only", default=None, help="run a single variant name")
    args = ap.parse_args()

    from repro.launch import dryrun_lib

    arch, shape, variants = variants_for(args.pair)
    rows = []
    for name, kw in variants:
        if args.only and name != args.only:
            continue
        r = dryrun_lib.run_dryrun(arch, shape, probe=True, **kw)
        row = r.to_json()
        row["variant"] = name
        rows.append(row)
        rep = r.report or {}
        print(f"{name:26s} ok={r.ok} compute={rep.get('compute_s', 0):.3f}s "
              f"memory={rep.get('memory_s', 0):.3f}s "
              f"collective={rep.get('collective_s', 0):.3f}s "
              f"dominant={rep.get('dominant', '-')} "
              f"useful={rep.get('useful_flops_ratio', 0):.3f} "
              f"err={r.error[:120]}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "a") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")


if __name__ == "__main__":
    main()
