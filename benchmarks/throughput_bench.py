"""Measured async throughput under wave-size churn, and sim calibration.

``BENCH_async.json`` gates a PREDICTED speedup on the simulated clock;
this bench measures the real thing: wall-clock ``client_updates_per_sec``
of the buffered-async loop on a ragged (non-IID-sized) federation whose
wave shapes churn, with the two dispatch modes the async executor
supports:

  * ``async-singlestream`` — the historical path: variable wave shapes
    (one retrace per distinct cohort geometry) and a host sync per wave;
  * ``async-pipelined`` — fixed-slot waves padded to the buffer size
    through the phantom-client masks (exactly ONE compiled round body for
    the whole run, proven by the ``compile_count`` telemetry) plus
    deferred host syncs (``jax.block_until_ready`` only at aggregation),
    so wave N+1's dispatch overlaps wave N's in-flight work.

Both modes aggregate bit-identical histories (pinned by
``tests/test_async_executor.py``); only scheduling differs, so the ratio
``pipeline_speedup`` is pure overhead reduction.  The bench also
calibrates ``systemsim.base_step_time`` against a measured per-step
device time (``systemsim.measure_step_time`` on the model's jitted SGD
step) and records how the calibrated virtual clock's wall prediction
compares to the measured wall (``calibration_ratio``).

Writes ``BENCH_throughput.json`` at the repo root — the artifact the
nightly ``throughput-bench`` job gates via ``compare_bench.py``
(``client_updates_per_sec``/``pipeline_speedup`` higher-is-better,
``compile_count`` lower-is-better).  The acceptance criterion — pipelined
throughput >= 1.2x single-stream on the forced 8-device host mesh — is
enforced in-run via ``--min-speedup``:

    PYTHONPATH=src python benchmarks/throughput_bench.py --host-devices 8
    PYTHONPATH=src python benchmarks/throughput_bench.py \
        --algos fedgkd --rounds 20 --min-speedup 0
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# client sizes are RAGGED so variable-wave mode sees churning (S, B, rows)
# geometries — the retrace pressure the fixed-slot mode eliminates
SIZES = (20, 45, 64, 100, 130, 150, 38, 75, 110, 24, 88, 140, 52, 96, 30, 66)


def _force_host_devices(n: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}").strip()


def make_data(task, seed: int = 0):
    import numpy as np

    from repro.data.pipeline import ClientData, FederatedData
    from repro.data.synthetic import make_task_data

    xtr, ytr, xte, yte = make_task_data(task, sum(SIZES), 400, seed=seed)
    clients, off = [], 0
    for s in SIZES:
        clients.append(ClientData(xtr[off:off + s], ytr[off:off + s]))
        off += s
    return FederatedData(clients, xte, yte,
                         np.zeros((len(SIZES), task.num_classes)))


def measured_step_time(model, data, batch_size: int) -> float:
    """Per-step device seconds of the model's jitted SGD step on a
    full-size batch — the ``base_step_time`` calibration input."""
    import jax
    import jax.numpy as jnp
    import optax

    from repro.core.systemsim import measure_step_time

    params = model.init(jax.random.PRNGKey(0))
    xb = jnp.asarray(data.clients[0].x[:batch_size])
    yb = jnp.asarray(data.clients[0].y[:batch_size])

    def loss_fn(p, x, y):
        logits = model.apply(p, x)
        return optax.softmax_cross_entropy_with_integer_labels(
            logits, y).mean()

    @jax.jit
    def step(p, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda w, gw: w - 0.05 * gw, p, g)

    return measure_step_time(step, params, xb, yb, warmup=2, repeats=5)


def run_mode(algo_name: str, task, data, args, *, pipelined: bool):
    from repro.core import algorithms, fl_loop
    from repro.core.executor import AsyncExecutor
    from repro.core.systemsim import Availability, SpeedProfile

    ex = AsyncExecutor(
        buffer_size=args.buffer, staleness="fedgkd", staleness_a=0.5,
        staleness_cutoff=4,
        profile=SpeedProfile(kind="straggler",
                             straggler_frac=args.straggler_frac),
        availability=Availability(period=24.0, duty=0.8), inner="vmap",
        pipelined=pipelined, wave_slots="auto" if pipelined else "variable")
    t0 = time.perf_counter()
    hist = fl_loop.run_federated(task, algorithms.make(algo_name), data,
                                 seed=args.seed, rounds=args.rounds,
                                 eval_every=args.rounds, executor=ex)
    wall = time.perf_counter() - t0
    return hist, wall


def bench_algo(algo_name: str, task, data, args, step_s: float) -> list:
    rows = []
    results = {}
    for pipelined in (False, True):
        hist, wall = run_mode(algo_name, task, data, args,
                              pipelined=pipelined)
        updates = sum(len(r.sampled) for r in hist.records)
        mode = "async-pipelined" if pipelined else "async-singlestream"
        results[mode] = (hist, wall, updates)
        rows.append({
            "algo": algo_name, "executor": mode,
            "epochs": task.local_epochs, "precompute": True,
            "buffer_size": args.buffer, "rounds": args.rounds,
            "wall_s": round(wall, 3),
            "client_updates": updates,
            "client_updates_per_sec": round(updates / wall, 3),
            "compile_count": hist.telemetry.get("compile_count"),
            "final_sim_time": round(float(hist.records[-1].sim_time), 2),
        })
    hist_p, wall_p, _ = results["async-pipelined"]
    row_p = rows[-1]
    row_p["pipeline_speedup"] = round(
        row_p["client_updates_per_sec"] / rows[0]["client_updates_per_sec"],
    4)
    # calibration: with base_step_time = measured per-step seconds the
    # virtual clock reads in predicted wall seconds (the clock scales
    # linearly in base_step_time, so scale rather than rerun).  The sim
    # models the FLEET's concurrent wall-clock; the measured wall serializes
    # every wave through one host mesh, so the ratio reads as the host's
    # effective client-parallelism, not an error bar.
    predicted = float(hist_p.records[-1].sim_time) * step_s
    row_p["base_step_time_calibrated_s"] = round(step_s, 6)
    row_p["predicted_wall_s"] = round(predicted, 3)
    row_p["calibration_ratio"] = round(wall_p / predicted, 4)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algos", nargs="+", default=["fedavg", "fedgkd"])
    ap.add_argument("--rounds", type=int, default=30,
                    help="async aggregations per mode (30 exercises the "
                         "churn window the compile_count criterion names)")
    ap.add_argument("--buffer", type=int, default=4)
    ap.add_argument("--clients-in-flight", type=int, default=8,
                    dest="n_sample")
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--straggler-frac", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force an N-device host mesh (must be set before "
                         "jax initializes)")
    ap.add_argument("--min-speedup", type=float, default=1.2,
                    help="fail if pipelined throughput < this multiple of "
                         "single-stream (0 disables the gate)")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_throughput.json"))
    args = ap.parse_args(argv)

    if args.host_devices > 0:
        _force_host_devices(args.host_devices)
    import jax

    if args.host_devices > 0 and len(jax.devices()) != args.host_devices:
        print(f"host mesh forcing failed: wanted {args.host_devices} "
              f"devices, jax sees {len(jax.devices())} (jax already "
              f"initialized?)")
        return 2

    from repro.configs.paper import TOY

    task = dataclasses.replace(TOY, n_clients=len(SIZES),
                               participation=args.n_sample / len(SIZES),
                               batch_size=args.batch_size,
                               local_epochs=args.local_epochs)
    data = make_data(task, seed=args.seed)

    from repro.core.modelzoo import make_model

    step_s = measured_step_time(make_model(task), data, args.batch_size)
    print(f"calibrated per-step device time: {step_s * 1e3:.3f} ms")

    cases = []
    for algo_name in args.algos:
        rows = bench_algo(algo_name, task, data, args, step_s)
        cases.extend(rows)
        base, pipe = rows
        print(f"{algo_name:>12}: single-stream "
              f"{base['client_updates_per_sec']:8.2f} up/s "
              f"(compiles {base['compile_count']}); pipelined "
              f"{pipe['client_updates_per_sec']:8.2f} up/s "
              f"(compiles {pipe['compile_count']}) -> "
              f"{pipe['pipeline_speedup']:.2f}x")

    payload = {"task": "toy-ragged", "devices": len(jax.devices()),
               "backend": jax.default_backend(), "clients": args.n_sample,
               "width": 16, "buffer": args.buffer,
               "profile": "straggler", "cases": cases}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")

    if args.min_speedup > 0:
        bad = [c for c in cases
               if c["executor"] == "async-pipelined"
               and c["pipeline_speedup"] < args.min_speedup]
        if bad:
            print(f"FAIL: {len(bad)} case(s) under the "
                  f">= {args.min_speedup:.1f}x pipeline-speedup criterion: "
                  f"{[(c['algo'], c['pipeline_speedup']) for c in bad]}")
            return 1
        print(f"all cases >= {args.min_speedup:.1f}x single-stream")
    return 0


if __name__ == "__main__":
    sys.exit(main())
