"""Population-tier benchmark: million-client cohorts with bounded host RSS.

Two measurements, written to ``BENCH_population.json`` at the repo root (the
nightly ``population-bench`` job gates it via ``compare_bench.py``):

  * SAMPLING — mean wall-clock of one hierarchical K-cohort draw at the
    full population vs a 10k control.  The draw is O(n_shards + cohort);
    the recorded ``sample_ratio_1m_vs_10k`` pins "cost independent of
    population" (the run itself fails if the ratio exceeds 2x, before any
    baseline comparison).  A flat ``rng.choice`` over the million ids is
    timed alongside as the O(population) reference the tier replaced.

  * END-TO-END — ``run_federated(population=...)`` over the full synthetic
    population for a few rounds with a ``--warm-cap`` working set, on the
    shard_map route when the host has multiple devices (force them with
    ``--host-devices 8``).  Records ``peak_host_rss_mb`` (VmHWM — the
    memory bound the warm cap holds) plus the tier counters; the run fails
    in-place if ``peak_warm`` ever exceeded the cap.

    PYTHONPATH=src python benchmarks/population_bench.py --host-devices 8
    PYTHONPATH=src python benchmarks/population_bench.py \
        --population 100000 --rounds 2            # faster local smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time

import jax
import numpy as np

from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.population import HierarchicalSampler, Population, even_shard_sizes

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def peak_rss_mb() -> float:
    """Process high-water RSS in MB (VmHWM; ru_maxrss fallback)."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return float(line.split()[1]) / 1024.0
    except OSError:
        pass
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def time_sampling(n_clients: int, shard_size: int, k: int, reps: int,
                  seed: int = 0) -> float:
    """Mean milliseconds per K-cohort hierarchical draw at ``n_clients``."""
    sampler = HierarchicalSampler(even_shard_sizes(n_clients, shard_size))
    rng = np.random.default_rng(seed)
    sampler.sample(rng, k)                       # warm any lazy state
    t0 = time.perf_counter()
    for _ in range(reps):
        sampler.sample(rng, k)
    return (time.perf_counter() - t0) / reps * 1e3


def time_flat_choice(n_clients: int, k: int, reps: int,
                     seed: int = 0) -> float:
    """The historical O(population) draw, for the comparison table."""
    rng = np.random.default_rng(seed)
    rng.choice(n_clients, size=k, replace=False)
    t0 = time.perf_counter()
    for _ in range(reps):
        rng.choice(n_clients, size=k, replace=False)
    return (time.perf_counter() - t0) / reps * 1e3


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=1_000_000)
    ap.add_argument("--control-population", type=int, default=10_000,
                    help="the small population the sampling ratio compares "
                         "against (cost must be within 2x of it)")
    ap.add_argument("--cohort", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--warm-cap", type=int, default=256,
                    help="warm-tier client cap == the host memory bound")
    ap.add_argument("--shard-size", type=int, default=4096)
    ap.add_argument("--sample-reps", type=int, default=200)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force this many XLA host-platform devices (the "
                         "multi-device shard_map case on a CPU box); must "
                         "run before jax initializes a backend")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_population.json"))
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}"
        ).strip()
        if len(jax.devices()) != args.host_devices:
            sys.exit(f"--host-devices {args.host_devices} requested but jax "
                     f"already initialized {len(jax.devices())} device(s); "
                     f"set XLA_FLAGS in the environment instead")

    n, k = args.population, args.cohort

    # -- sampling: O(cohort) draw must not scale with the population -------
    big_ms = time_sampling(n, args.shard_size, k, args.sample_reps)
    small_ms = time_sampling(args.control_population, args.shard_size, k,
                             args.sample_reps)
    flat_ms = time_flat_choice(n, k, max(args.sample_reps // 10, 5))
    ratio = big_ms / small_ms
    print(f"sampling K={k}: {n:,} clients {big_ms:.4f} ms | "
          f"{args.control_population:,} clients {small_ms:.4f} ms | "
          f"ratio {ratio:.2f}x | flat rng.choice({n:,}) {flat_ms:.3f} ms")
    if ratio > 2.0:
        print(f"FAIL: hierarchical draw at {n:,} clients is {ratio:.2f}x "
              f"the {args.control_population:,}-client cost (limit 2x) — "
              f"sampling is no longer population-independent")
        return 1

    # -- end-to-end: bounded-RSS training over the full population ---------
    population = Population.synthetic(n, warm_cap=args.warm_cap,
                                      shard_size=args.shard_size,
                                      min_n=8, max_n=24, seed=0, n_test=128)
    task = dataclasses.replace(TOY, n_clients=n, participation=k / n,
                               rounds=args.rounds, local_epochs=1,
                               batch_size=16)
    route = "shard_map" if len(jax.devices()) > 1 else "vmap"
    rss_before = peak_rss_mb()
    t0 = time.perf_counter()
    hist = fl_loop.run_federated(task, algorithms.make("fedavg"),
                                 population=population, seed=0,
                                 executor=route, width=args.width,
                                 eval_every=max(args.rounds, 1))
    wall = time.perf_counter() - t0
    stats = hist.telemetry["population"]
    rss = peak_rss_mb()
    print(f"e2e [{route}] {args.rounds} rounds x K={k} over {n:,} clients: "
          f"{wall:.1f} s wall, peak RSS {rss:.0f} MB "
          f"(before run: {rss_before:.0f} MB)")
    print(f"    tiers: cold_loads={stats['cold_loads']} "
          f"warm_hits={stats['warm_hits']} peak_warm={stats['peak_warm']} "
          f"warm_evictions={stats['warm_evictions']} "
          f"n_shards={stats['n_shards']}")
    if stats["peak_warm"] > args.warm_cap:
        print(f"FAIL: peak_warm {stats['peak_warm']} exceeded the warm cap "
              f"{args.warm_cap} — the memory bound did not hold")
        return 1

    payload = {
        "task": "toy",
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "clients": k,
        "width": args.width,
        "population": n,
        "warm_cap": args.warm_cap,
        "shard_size": args.shard_size,
        "cases": [
            {"algo": "sampler", "executor": "host", "epochs": 0,
             "precompute": False, "population": n,
             "sample_latency_ms": round(big_ms, 5),
             "sample_latency_small_ms": round(small_ms, 5),
             "sample_ratio_1m_vs_10k": round(ratio, 4),
             "flat_choice_ms": round(flat_ms, 4),
             "control_population": args.control_population,
             "cohort": k, "n_shards": int(population.n_shards)},
            {"algo": "fedavg", "executor": route, "epochs": 1,
             "precompute": False, "population": n,
             "peak_host_rss_mb": round(rss, 1),
             "rss_before_run_mb": round(rss_before, 1),
             "wall_s": round(wall, 2), "rounds": args.rounds,
             "cohort": k, "warm_cap": args.warm_cap,
             "final_acc": hist.records[-1].test_acc,
             **{f"tier_{key}": val for key, val in stats.items()
                if isinstance(val, (int, float))}},
        ],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
