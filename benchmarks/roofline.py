"""§Roofline reporter: turn dry-run JSON lines into the per-(arch × shape)
three-term roofline table (compute / memory / collective seconds, dominant
term, MODEL_FLOPS ratio).

    PYTHONPATH=src python -m benchmarks.roofline --in results/dryrun.jsonl
"""
from __future__ import annotations

import argparse
import json


COLS = ("arch", "shape", "mesh", "kd", "compute_s", "memory_s",
        "collective_s", "dominant", "useful", "fit")


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            if line.strip():
                rows.append(json.loads(line))
    return rows


def format_table(rows: list[dict]) -> str:
    out = ["| " + " | ".join(COLS) + " |",
           "|" + "|".join(["---"] * len(COLS)) + "|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r.get('kd_mode','-')} | — | — | — | "
                       f"{r.get('error','')[:40]} | — | — |")
            continue
        rep = r.get("report") or {}
        mem = r.get("memory", {})
        # per-device live bytes ≈ args + temps (outputs alias args on donation)
        live = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
        fits = "Y" if live < 16 << 30 else f"N({live/2**30:.0f}G)"
        out.append(
            "| {arch} | {shape} | {mesh} | {kd} | {c:.4f} | {m:.4f} | "
            "{x:.4f} | {dom} | {u:.2f} | {fit} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                kd=r.get("kd_mode", "-"), c=rep.get("compute_s", 0),
                m=rep.get("memory_s", 0), x=rep.get("collective_s", 0),
                dom=rep.get("dominant", "-"),
                u=rep.get("useful_flops_ratio", 0), fit=fits))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", required=True)
    args = ap.parse_args()
    print(format_table(load(args.inp)))


if __name__ == "__main__":
    main()
