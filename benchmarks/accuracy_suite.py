"""Right-sized accuracy suite: fills every paper-table section of
EXPERIMENTS.md in one pass, prioritizing the α=0.1 (strong non-IID)
comparisons where the paper's claims live.  Histories are reused so the
round-trajectory table costs nothing extra."""
import dataclasses
import json


from benchmarks.common import make_algo
from repro.configs.paper import CIFAR10, SST5, scaled
from repro.core import algorithms, fl_loop

METHODS = ["fedavg", "fedprox", "moon", "feddistill+", "fedgen",
           "fedgkd", "fedgkd-vote", "fedgkd+"]


def main():
    out = {}
    # --- Table 3 core: CIFAR-like, α=0.1, all 8 methods ------------------
    task = scaled(CIFAR10, 0.05, rounds=8, local_epochs=2)
    data01 = fl_loop.make_federated_data(task, alpha=0.1, seed=0, n_test=400)
    rows = []
    for m in METHODS:
        h = fl_loop.run_federated(task, make_algo(m, task), data01, seed=0)
        rows.append({"method": m, "alpha": 0.1, "best": h.best_acc,
                     "final": h.final_acc, "local": h.local_model_acc,
                     "history": h.accs()})
        print(f"t3 a0.1 {m:12s} best={h.best_acc:.4f} final={h.final_acc:.4f} "
              f"local={h.local_model_acc:.4f}", flush=True)
    out["table3_alpha01"] = rows

    # --- Table 5: participation C in {0.1, 0.4}, fedavg vs fedgkd ---------
    rows = []
    for c in (0.1, 0.4):
        t5 = dataclasses.replace(task, participation=c)
        d5 = fl_loop.make_federated_data(t5, alpha=0.5, seed=0, n_test=400)
        for m in ("fedavg", "fedgkd"):
            h = fl_loop.run_federated(t5, make_algo(m, t5), d5, seed=0)
            rows.append({"method": m, "C": c, "best": h.best_acc,
                         "final": h.final_acc})
            print(f"t5 C={c} {m:8s} best={h.best_acc:.4f}", flush=True)
    out["table5"] = rows

    # --- Table 7/8: buffer M in {1,5} ------------------------------------
    rows = []
    for m_buf in (1, 5):
        for m in ("fedgkd", "fedgkd-vote"):
            h = fl_loop.run_federated(task, make_algo(m, task, buffer_m=m_buf),
                                      data01, seed=0)
            rows.append({"method": m, "M": m_buf, "best": h.best_acc,
                         "final": h.final_acc})
            print(f"t7 M={m_buf} {m:12s} best={h.best_acc:.4f}", flush=True)
    out["table7"] = rows

    # --- Table 9: none/mse/kl --------------------------------------------
    rows = []
    for lt in ("none", "mse", "kl"):
        algo = (algorithms.make("fedavg") if lt == "none" else
                algorithms.make("fedgkd", gamma=task.gamma, buffer_m=1,
                                loss_type=lt))
        h = fl_loop.run_federated(task, algo, data01, seed=0)
        rows.append({"loss": lt, "best": h.best_acc, "final": h.final_acc})
        print(f"t9 {lt:5s} best={h.best_acc:.4f}", flush=True)
    out["table9"] = rows

    # --- Table 4: SST5-like, 4 methods ------------------------------------
    t4 = scaled(SST5, 0.3, rounds=6, local_epochs=2)
    d4 = fl_loop.make_federated_data(t4, alpha=0.1, seed=0, n_test=300)
    rows = []
    for m in ("fedavg", "fedprox", "fedgkd", "fedgkd-vote"):
        h = fl_loop.run_federated(t4, make_algo(m, t4), d4, seed=0)
        rows.append({"method": m, "best": h.best_acc, "final": h.final_acc})
        print(f"t4 {m:12s} best={h.best_acc:.4f}", flush=True)
    out["table4"] = rows

    with open("results/accuracy_suite.json", "w") as f:
        json.dump(out, f, indent=1)
    print("WROTE results/accuracy_suite.json")


if __name__ == "__main__":
    main()
