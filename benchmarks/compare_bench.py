"""Gate a fresh bench run against the committed baseline.

Compares the per-case SPEEDUP ratios of two bench JSON files — ratios, not
wall-clock, so a slower CI runner does not read as a regression.  Handles
both artifacts with the shared ``cases`` schema:

  * ``BENCH_executor.json`` — ``speedup_vs_sequential`` /
    ``speedup_vs_no_precompute`` (executor pipeline vs references);
  * ``BENCH_async.json`` — ``sim_speedup_vs_sync`` (simulated wall-clock
    to target accuracy, async vs the synchronous straggler barrier);
  * ``BENCH_conv.json`` — ``speedup_vs_naive_vmap`` (client-batched
    grouped-conv round body vs the historical vmapped-conv body on the
    resnet8 cohort);
  * ``BENCH_population.json`` — LOWER-is-better resource metrics from the
    million-client population-tier run: ``peak_host_rss_mb`` (the warm-cap
    memory bound held) and ``sample_latency_ms`` (the O(cohort) draw), plus
    the population-independence ratio ``sample_ratio_1m_vs_10k``;
  * ``BENCH_multihost.json`` — LOWER-is-better per-host resource metrics
    from the 2-process placement run (one case per host, keyed by the
    ``host`` field): ``peak_host_rss_mb`` and ``peak_warm`` — the
    sharded warm tiers must keep holding ``warm_cap // n_hosts`` — plus
    the chaos cases: ``async_client_updates_per_sec`` (higher-better —
    aggregated client updates per wall-second while the 2-host async run
    degrades through correlated host crashes and recovers) and
    ``host_crash_recovery_rounds`` (LOWER-is-better — rounds replayed
    past the agreed restore point after a mid-run host kill + coordinated
    resume);
  * ``BENCH_faults.json`` — LOWER-is-better fault-tolerance metrics:
    ``acc_drop_at_20pct_crash`` (accuracy lost at the heaviest fault cell
    vs fault-free) and ``overhead_ratio`` (retry re-dispatches per
    completed round; deterministic under the seeded injector);
  * ``BENCH_throughput.json`` — measured async throughput under wave
    churn: ``client_updates_per_sec`` and ``pipeline_speedup``
    (pipelined fixed-slot dispatch vs the single-stream baseline,
    higher-better) plus ``compile_count`` (traced round bodies across the
    run, LOWER-is-better — fixed-slot waves pin it to 1).

A case is keyed by ``(algo, executor, epochs, precompute, buffer_size,
model, conv_route, population, faults)`` (trailing fields ``None`` for
artifacts predating them); only keys present in BOTH files are compared (the
baseline may predate newer cases), and a metric regresses when

    new_speedup < baseline_speedup * (1 - tolerance)      # higher-better
    new_cost    > baseline_cost    * (1 + tolerance)      # lower-better

Exit code 1 on any regression — the nightly CI jobs fail on it.

    python benchmarks/compare_bench.py BENCH_executor.json BENCH_new.json \
        --tolerance 0.20
    python benchmarks/compare_bench.py BENCH_async.json BENCH_async_new.json
"""
from __future__ import annotations

import argparse
import json

METRICS = ("speedup_vs_sequential", "speedup_vs_no_precompute",
           "sim_speedup_vs_sync", "speedup_vs_naive_vmap",
           "client_updates_per_sec", "pipeline_speedup",
           "async_client_updates_per_sec")
# resource costs: regression direction is inverted (new may not EXCEED
# baseline * (1 + tolerance)) — an RSS or latency DROP is never a failure
METRICS_LOWER = ("peak_host_rss_mb", "sample_latency_ms",
                 "sample_ratio_1m_vs_10k", "acc_drop_at_20pct_crash",
                 "overhead_ratio", "compile_count", "peak_warm",
                 "rss_ratio_vs_single", "host_crash_recovery_rounds")


def case_key(row: dict) -> tuple:
    return (row["algo"], row["executor"], row["epochs"],
            bool(row.get("precompute")), row.get("buffer_size"),
            row.get("model"), row.get("conv_route"), row.get("population"),
            row.get("faults"), row.get("host"))


def index_cases(payload: dict) -> dict:
    return {case_key(r): r for r in payload["cases"]}


def compare(baseline: dict, fresh: dict, tolerance: float) -> list[dict]:
    """Rows of {key, metric, base, new, ok}; only shared keys+metrics."""
    base_idx, new_idx = index_cases(baseline), index_cases(fresh)
    rows = []
    for key in sorted(set(base_idx) & set(new_idx), key=str):
        for metric in METRICS + METRICS_LOWER:
            b = base_idx[key].get(metric)
            n = new_idx[key].get(metric)
            if b is None or n is None:
                continue
            if metric in METRICS_LOWER:
                ok = float(n) <= float(b) * (1.0 + tolerance)
            else:
                ok = float(n) >= float(b) * (1.0 - tolerance)
            rows.append({"key": key, "metric": metric, "base": float(b),
                         "new": float(n), "ok": ok})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_executor.json")
    ap.add_argument("fresh", help="the run to validate")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional speedup drop (default 20%%)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    # speedup ratios transfer across runner speeds but NOT across execution
    # environments: a 1-device "shard_map" row would be the vmap fallback
    for field in ("devices", "backend", "clients", "width"):
        b, n = baseline.get(field), fresh.get(field)
        if b is not None and n is not None and b != n:
            print(f"compare_bench: refusing to compare — baseline "
                  f"{field}={b} but fresh run has {field}={n}; regenerate "
                  f"with matching settings")
            return 2
    rows = compare(baseline, fresh, args.tolerance)
    if not rows:
        print("compare_bench: no overlapping cases — nothing to gate")
        return 0

    bad = [r for r in rows if not r["ok"]]
    width = max(len(str(r["key"])) for r in rows)
    print(f"{'case':<{width}}  {'metric':<26} {'base':>7} {'new':>7}  ok")
    for r in rows:
        print(f"{str(r['key']):<{width}}  {r['metric']:<26} "
              f"{r['base']:>7.3f} {r['new']:>7.3f}  "
              f"{'ok' if r['ok'] else 'REGRESSED'}")
    if bad:
        print(f"\n{len(bad)} metric(s) regressed by more than "
              f"{args.tolerance:.0%} vs {args.baseline}")
        return 1
    print(f"\nall {len(rows)} shared metrics within {args.tolerance:.0%} "
          f"of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
