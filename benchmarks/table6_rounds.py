"""Paper Table 6 / Fig. 4: accuracy trajectory over communication rounds
(robustness: FedGKD keeps improving where others oscillate)."""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, run_methods
from repro.configs.paper import CIFAR10


def run(preset: str = "fast"):
    cfgs = {
        "fast": dict(scale=0.02, rounds=4, methods=["fedavg", "fedgkd"]),
        "medium": dict(scale=0.05, rounds=12,
                       methods=["fedavg", "fedprox", "fedgkd", "fedgkd-vote"]),
        "full": dict(scale=0.1, rounds=25,
                     methods=["fedavg", "fedprox", "moon", "feddistill+",
                              "fedgen", "fedgkd", "fedgkd-vote", "fedgkd+"]),
    }[preset]
    rows = run_methods(CIFAR10, cfgs["methods"], [0.1], trials=1,
                       scale=cfgs["scale"], rounds=cfgs["rounds"],
                       local_epochs=2)
    # checkpoints at 25/50/75/100% of the budget
    out = []
    for r in rows:
        hist = r["history"]
        n = len(hist)
        for frac in (0.25, 0.5, 0.75, 1.0):
            idx = max(0, int(round(frac * n)) - 1)
            out.append({"method": r["method"], "round": idx + 1,
                        "acc": hist[idx]})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="medium",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    rows = run(args.preset)
    print(csv_rows(rows, ["method", "round", "acc"]))


if __name__ == "__main__":
    main()
