"""Main benchmark entry: runs the fast preset of every paper-table bench and
prints ``name,us_per_call,derived`` CSV (deliverable (d)).

    PYTHONPATH=src python -m benchmarks.run [--preset fast|medium|full]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="fast",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    preset = args.preset

    from benchmarks import (kernel_bench, table3_cv, table4_nlp,
                            table5_participation, table6_rounds,
                            table7_buffer, table9_losstype)


    def bench(name, fn):
        t0 = time.time()
        out = fn(preset)
        us = (time.time() - t0) * 1e6
        return name, us, out

    print("name,us_per_call,derived")
    for name, runner, derive in [
        ("table3_cv", table3_cv.run,
         lambda rs: "fedgkd_best=" + "/".join(
             f"{r['best_mean']:.3f}" for r in rs if r["method"] == "fedgkd")),
        ("table4_nlp", table4_nlp.run,
         lambda rs: "fedgkd_best=" + "/".join(
             f"{r['best_mean']:.3f}" for r in rs if r["method"] == "fedgkd")),
        ("table5_participation", table5_participation.run,
         lambda rs: "n_rows=%d" % len(rs)),
        ("table6_rounds", table6_rounds.run,
         lambda rs: "final_accs=" + "/".join(
             f"{r['acc']:.3f}" for r in rs if r["round"] == max(
                 x["round"] for x in rs))),
        ("table7_buffer", table7_buffer.run,
         lambda rs: "n_rows=%d" % len(rs)),
        ("table9_losstype", table9_losstype.run,
         lambda rs: "best=" + "/".join(
             f"{r['loss_type']}:{r['best']:.3f}" for r in rs)),
    ]:
        name_, us, out = bench(name, runner)
        print(f"{name_},{us:.0f},{derive(out)}", flush=True)

    for r in kernel_bench.run(preset):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}", flush=True)


if __name__ == "__main__":
    main()
