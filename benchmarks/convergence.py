"""Theorem 3 sanity: FedGKD should drive min_t ‖∇f(w_t)‖ down ~ O(1/T).

We track the GLOBAL objective's gradient norm at the server model after each
round (computable exactly on the small synthetic task: f = Σ p_k F_k) and
report the running minimum — the quantity Theorem 3 bounds.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.paper import CIFAR10, scaled
from repro.core import algorithms, fl_loop
from repro.core.distillation import cross_entropy
from repro.optim import global_norm


def global_grad_norm(model, params, data) -> float:
    """‖∇ Σ_k (n_k/n) F_k(w)‖ over all client data."""
    def f(p):
        total, n = 0.0, 0
        for c in data.clients:
            logits = model.apply(p, jnp.asarray(c.x))
            total = total + cross_entropy(logits, jnp.asarray(c.y)) * c.n
            n += c.n
        return total / n
    return float(global_norm(jax.grad(f)(params)))


def run(rounds: int = 8, scale: float = 0.02, alpha: float = 0.1,
        seed: int = 0):
    task = scaled(CIFAR10, scale, rounds=1, local_epochs=2)
    data = fl_loop.make_federated_data(task, alpha=alpha, seed=seed,
                                       n_test=200)
    rows = []
    for name in ("fedavg", "fedgkd"):
        algo = (algorithms.make("fedgkd", gamma=0.2, buffer_m=3)
                if name == "fedgkd" else algorithms.make(name))
        norms: list[float] = []

        def cb(rnd, server, model):
            norms.append(global_grad_norm(model, server["global"], data))

        fl_loop.run_federated(task, algo, data, seed=seed, rounds=rounds,
                              round_callback=cb)
        run_min = [min(norms[: i + 1]) for i in range(len(norms))]
        rows.append({"method": name, "grad_norms": norms,
                     "running_min": run_min})
        print(f"{name}: grad-norm running min "
              f"{' -> '.join(f'{x:.3f}' for x in run_min)}", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()
    run(rounds=args.rounds)


if __name__ == "__main__":
    main()
