"""Paper Table 4: NLP classification (AG-News / SST-5 stand-ins, α=0.1)."""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, run_methods
from repro.configs.paper import AG_NEWS, SST5

METHODS = ["fedavg", "fedprox", "moon", "feddistill+", "fedgen",
           "fedgkd", "fedgkd-vote", "fedgkd+"]


def run(preset: str = "fast"):
    cfgs = {
        "fast": dict(scale=0.05, rounds=2, trials=1, tasks=[SST5],
                     methods=["fedavg", "fedgkd"]),
        "medium": dict(scale=0.2, rounds=6, trials=2, tasks=[SST5],
                       methods=METHODS),
        "full": dict(scale=0.5, rounds=10, trials=3, tasks=[AG_NEWS, SST5],
                     methods=METHODS),
    }[preset]
    rows = []
    for task in cfgs["tasks"]:
        rows += run_methods(task, cfgs["methods"], [0.1],
                            trials=cfgs["trials"], scale=cfgs["scale"],
                            rounds=cfgs["rounds"])
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="medium",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    rows = run(args.preset)
    print(csv_rows(rows, ["task", "method", "alpha", "best_mean", "best_std",
                          "final_mean", "seconds"]))


if __name__ == "__main__":
    main()
