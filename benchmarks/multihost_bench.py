"""Multi-host population placement benchmark: per-host memory bounds.

Measures the tentpole claim of the multi-host placement layer
(``repro.population.placement``): splitting a million-client population
across N host processes divides the warm/hot working set — each host's
``peak_warm`` stays inside ``warm_cap // n_hosts`` and its peak RSS lands
measurably below the single-host figure, while the 2-process shard_map run
still completes its rounds through the filesystem allgather exchange.

The coordinator spawns every measured run as a FRESH subprocess (its own
``--worker`` mode) so each VmHWM high-water mark is clean:

  * one single-host worker (``n_hosts=1``) — the baseline figure;
  * ``--n-hosts`` workers sharing an exchange dir — the distributed run.

Clients are deliberately fat (``--min-n/--max-n`` rows of ``--dim``
features) so the warm+hot tiers dominate interpreter noise in the RSS
comparison; ``max_batches_per_client`` keeps the CPU compute tiny.

Writes ``BENCH_multihost.json`` (one case per host, keyed by the ``host``
field) which the nightly ``multihost-bench`` job gates through
``compare_bench.py`` — ``peak_host_rss_mb`` and ``peak_warm`` are
lower-is-better.  The run itself FAILS in place if a host breaks its warm
bound or the per-host RSS is not below the single-host measurement.

``--chaos`` runs the fault-composition cases instead (``--all`` runs
both): the async executor under correlated host-crash + client faults
(``async_client_updates_per_sec``, higher-is-better — aggregated client
updates per wall-second while the fleet degrades and recovers) and a
mid-run hard kill of host 0 followed by a coordinated resume of the full
topology (``host_crash_recovery_rounds``, lower-is-better — rounds
replayed past the agreed restore point; sensitive to both the checkpoint
cadence and the min-over-hosts resume barrier).  The nightly
``multihost-chaos`` job gates these against the same committed baseline.

    PYTHONPATH=src python benchmarks/multihost_bench.py --host-devices 8
    PYTHONPATH=src python benchmarks/multihost_bench.py \
        --population 100000 --rounds 2            # faster local smoke
    PYTHONPATH=src python benchmarks/multihost_bench.py --chaos
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _worker(args) -> int:
    """One measured training run (single-host baseline or one rank)."""
    import jax

    from repro.configs.paper import TOY
    from repro.core import algorithms, fl_loop
    from repro.population import (HostPlacement, Population, peak_rss_mb)

    n, k = args.population, args.cohort
    placement = None
    if args.n_hosts > 1:
        placement = HostPlacement(args.host, args.n_hosts,
                                  exchange_dir=args.exchange,
                                  timeout_s=args.timeout)
    population = Population.synthetic(
        n, warm_cap=args.warm_cap, shard_size=args.shard_size,
        dim=args.dim, min_n=args.min_n, max_n=args.max_n, seed=0,
        n_test=128, placement=placement)
    task = dataclasses.replace(TOY, n_clients=n, participation=k / n,
                               rounds=args.rounds, local_epochs=1,
                               batch_size=64, feat_dim=args.dim)
    route = args.executor or ("shard_map" if len(jax.devices()) > 1
                              else "vmap")
    kw = {}
    chaos = bool(args.crash_prob or args.corrupt_prob
                 or args.host_crash_prob)
    if chaos:
        from repro.core.systemsim import FaultProfile
        kw["faults"] = FaultProfile(crash_prob=args.crash_prob,
                                    corrupt_prob=args.corrupt_prob,
                                    host_crash_prob=args.host_crash_prob)
    if args.ckpt:
        kw["checkpoint_dir"] = args.ckpt
        kw["resume"] = args.resume
    if args.die_at_round:
        die_at = args.die_at_round
        # hard kill mid-run: no atexit, no flushed result file — the
        # coordinator expects rc 17 and reads the surviving hosts only
        kw["round_callback"] = (
            lambda rnd, server, model: os._exit(17) if rnd == die_at
            else None)
    t0 = time.perf_counter()
    hist = fl_loop.run_federated(task, algorithms.make("fedavg"),
                                 population=population, seed=0,
                                 executor=route, width=args.width,
                                 eval_every=max(args.rounds, 1),
                                 max_batches_per_client=4, **kw)
    wall = time.perf_counter() - t0
    stats = hist.telemetry["population"]
    updates = sum(len(r.sampled or ()) for r in hist.records)
    result = {"host": (f"host{args.host}" if args.n_hosts > 1
                       else "single"),
              "n_hosts": args.n_hosts, "executor": route,
              "devices": len(jax.devices()),
              "wall_s": round(wall, 2),
              "client_updates": updates,
              "peak_host_rss_mb": round(peak_rss_mb(), 1),
              "final_acc": hist.records[-1].test_acc,
              **{f"tier_{key}": val for key, val in stats.items()
                 if isinstance(val, (int, float))},
              "peak_warm": int(stats["peak_warm"]),
              "warm_cap": stats["warm_cap"]}
    if route == "async":
        result["async_client_updates_per_sec"] = round(updates / wall, 2)
    if chaos:
        result["faults"] = (f"crash{args.crash_prob}"
                            f"+corrupt{args.corrupt_prob}"
                            f"+host{args.host_crash_prob}")
        ftel = hist.telemetry.get("faults") or {}
        for key in ("host_crashes", "host_timeouts", "crashes",
                    "corrupt_injected", "retries", "dropped_clients"):
            result[f"f_{key}"] = int(ftel.get(key, 0))
    with open(args.result, "w") as f:
        json.dump(result, f)
    print(f"[{result['host']}] {args.rounds} rounds x K={k} [{route}]: "
          f"{wall:.1f} s wall, peak RSS {result['peak_host_rss_mb']:.0f} MB, "
          f"peak_warm {result['peak_warm']} (cap {result['warm_cap']})")
    return 0


def _spawn(args, host: int, n_hosts: int, exchange: str,
           result: str, extra=()) -> subprocess.Popen:
    cmd = [sys.executable, __file__, "--worker", "--host", str(host),
           "--n-hosts", str(n_hosts), "--result", result,
           "--population", str(args.population), "--cohort",
           str(args.cohort), "--rounds", str(args.rounds), "--warm-cap",
           str(args.warm_cap), "--shard-size", str(args.shard_size),
           "--dim", str(args.dim), "--min-n", str(args.min_n), "--max-n",
           str(args.max_n), "--width", str(args.width), "--timeout",
           str(args.timeout)]
    if exchange:
        cmd += ["--exchange", exchange]
    cmd += list(extra)          # argparse keeps the LAST occurrence: extra
    env = dict(os.environ)      # may override --rounds etc. per case
    env.pop("XLA_FLAGS", None)
    if args.host_devices:
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{args.host_devices}")
    env.setdefault("PYTHONPATH", str(REPO_ROOT / "src"))
    return subprocess.Popen(cmd, env=env)


def _collect(procs, results, expect=None) -> list:
    """Wait for every worker and load its result JSON.  ``expect`` maps
    each worker to its expected return code (default 0 for all) — the
    hard-kill chaos case expects 17 from the killed rank, whose slot in
    ``results`` is then ``None`` (it died before writing a file)."""
    for i, p in enumerate(procs):
        rc = p.wait()
        want = 0 if expect is None else expect[i]
        if rc != want:
            sys.exit(f"worker {i} exited {rc} (expected {want})")
    out = []
    for path in results:
        if path is None:
            out.append(None)
            continue
        with open(path) as f:
            out.append(json.load(f))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--host", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--exchange", default="", help=argparse.SUPPRESS)
    ap.add_argument("--result", default="", help=argparse.SUPPRESS)
    ap.add_argument("--executor", default="", help=argparse.SUPPRESS)
    ap.add_argument("--crash-prob", type=float, default=0.0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--corrupt-prob", type=float, default=0.0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--host-crash-prob", type=float, default=0.0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--ckpt", default="", help=argparse.SUPPRESS)
    ap.add_argument("--resume", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--die-at-round", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-composition cases (async under "
                         "correlated host crashes + kill-then-resume) "
                         "instead of the memory-bound cases")
    ap.add_argument("--all", dest="all_cases", action="store_true",
                    help="run the memory-bound AND the chaos cases into "
                         "one payload")
    ap.add_argument("--n-hosts", type=int, default=2,
                    help="emulated host processes for the distributed run")
    ap.add_argument("--population", type=int, default=1_000_000)
    ap.add_argument("--cohort", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--warm-cap", type=int, default=256,
                    help="GLOBAL warm cap; each host keeps cap // n_hosts")
    ap.add_argument("--shard-size", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--min-n", type=int, default=2048)
    ap.add_argument("--max-n", type=int, default=4096)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--host-devices", type=int, default=8,
                    help="XLA host-platform devices per worker (0 = leave "
                         "XLA_FLAGS alone; workers then run the vmap route)")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_multihost.json"))
    args = ap.parse_args(argv)

    if args.worker:
        return _worker(args)

    cases: list = []
    failures: list = []
    devices = None
    if args.all_cases or not args.chaos:
        cases, failures, devices = _run_memory(args)
    if args.chaos or args.all_cases:
        ch_cases, ch_fail, ch_dev = _run_chaos(args)
        cases += ch_cases
        failures += ch_fail
        devices = devices if devices is not None else ch_dev
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}")
        return 1

    payload = {
        "task": "toy", "devices": devices,
        "backend": "cpu", "clients": args.cohort, "width": args.width,
        "population": args.population, "n_hosts": args.n_hosts,
        "dim": args.dim, "min_n": args.min_n, "max_n": args.max_n,
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


def _run_memory(args) -> tuple[list, list, int]:
    """The memory-bound cases (the original bench): fresh single-host
    baseline vs the n-host split, per-host warm/RSS bounds enforced."""
    with tempfile.TemporaryDirectory(prefix="repro_mh_bench_") as tmp:
        # -- single-host baseline (fresh process: clean VmHWM) -------------
        single_res = os.path.join(tmp, "single.json")
        single = _collect([_spawn(args, 0, 1, "", single_res)],
                          [single_res])[0]

        # -- the distributed run: n_hosts workers, shared exchange dir -----
        exch = os.path.join(tmp, "exchange")
        results = [os.path.join(tmp, f"host{h}.json")
                   for h in range(args.n_hosts)]
        hosts = _collect(
            [_spawn(args, h, args.n_hosts, exch, results[h])
             for h in range(args.n_hosts)], results)

    per_host_cap = max(1, args.warm_cap // args.n_hosts)
    max_rss = max(h["peak_host_rss_mb"] for h in hosts)
    print(f"\nsingle host: peak RSS {single['peak_host_rss_mb']:.0f} MB, "
          f"peak_warm {single['peak_warm']} (cap {args.warm_cap})")
    print(f"{args.n_hosts} hosts:     max peak RSS {max_rss:.0f} MB, "
          f"peak_warm {[h['peak_warm'] for h in hosts]} "
          f"(per-host cap {per_host_cap})")

    failures = []
    if single["peak_warm"] > args.warm_cap:
        failures.append(f"single-host peak_warm {single['peak_warm']} "
                        f"exceeded cap {args.warm_cap}")
    for h in hosts:
        # the synchronous round pins only the owned cohort slice, which
        # the per-host cap dominates at these settings — no excursion slack
        if h["peak_warm"] > per_host_cap:
            failures.append(f"{h['host']} peak_warm {h['peak_warm']} "
                            f"exceeded per-host cap {per_host_cap}")
        if h["final_acc"] != single["final_acc"]:
            failures.append(f"{h['host']} final_acc {h['final_acc']} != "
                            f"single-host {single['final_acc']} — the "
                            f"placement changed the numbers")
    if not max_rss < single["peak_host_rss_mb"] * 0.95:
        failures.append(f"max per-host RSS {max_rss:.0f} MB is not "
                        f"measurably below the single-host "
                        f"{single['peak_host_rss_mb']:.0f} MB")

    common = {"algo": "fedavg", "executor": single["executor"], "epochs": 1,
              "precompute": False, "population": args.population,
              "cohort": args.cohort, "rounds": args.rounds,
              "warm_cap": args.warm_cap}
    cases = ([dict(common, **single)]
             + [dict(common, **h) for h in hosts]
             + [dict(common, host="max_over_hosts",
                     peak_host_rss_mb=max_rss,
                     peak_warm=max(h["peak_warm"] for h in hosts),
                     rss_ratio_vs_single=round(
                         max_rss / single["peak_host_rss_mb"], 4))])
    return cases, failures, single["devices"]


def _newest_checkpoint_round(ckpt_dir: str, host: int):
    """Newest per-host checkpoint round on disk, or None."""
    import re

    pat = re.compile(rf"^state_host{host:03d}_(\d{{6}})\.npz$")
    rounds = [int(m.group(1)) for name in os.listdir(ckpt_dir)
              if (m := pat.match(name))]
    return max(rounds) if rounds else None


def _run_chaos(args) -> tuple[list, list, int]:
    """The fault-composition cases: the async executor under correlated
    host-crash + client faults (throughput while the fleet degrades and
    recovers), then a mid-run hard kill of host 0 followed by a
    coordinated resume of the whole topology (rounds replayed past the
    agreed restore point)."""
    fault_flags = ["--crash-prob", "0.05", "--corrupt-prob", "0.05",
                   "--host-crash-prob", "0.2"]
    common = {"algo": "fedavg", "epochs": 1, "precompute": False,
              "population": args.population, "cohort": args.cohort,
              "rounds": args.rounds, "warm_cap": args.warm_cap}
    cases: list = []
    failures: list = []
    with tempfile.TemporaryDirectory(prefix="repro_mh_chaos_") as tmp:
        # -- async under correlated faults: throughput while degraded ------
        exch = os.path.join(tmp, "exchange_async")
        results = [os.path.join(tmp, f"async_host{h}.json")
                   for h in range(args.n_hosts)]
        hosts = _collect(
            [_spawn(args, h, args.n_hosts, exch, results[h],
                    extra=["--executor", "async", *fault_flags])
             for h in range(args.n_hosts)], results)
        if len({h["final_acc"] for h in hosts}) != 1:
            failures.append(f"async chaos hosts diverged: final_acc "
                            f"{[h['final_acc'] for h in hosts]}")
        if not any(h["f_host_crashes"] for h in hosts):
            failures.append("async chaos run drew zero host crashes — the "
                            "correlated-fault path was not exercised")
        ups = min(h["async_client_updates_per_sec"] for h in hosts)
        print(f"\nasync chaos ({args.n_hosts} hosts): {ups:.1f} client "
              f"updates/s (min over hosts), "
              f"{hosts[0]['f_host_crashes']} correlated host crashes, "
              f"{hosts[0]['f_retries']} retries")
        cases += [dict(common, **h) for h in hosts]
        cases.append(dict(common, host="chaos_async_min",
                          executor="async", faults=hosts[0]["faults"],
                          async_client_updates_per_sec=ups))

        # -- mid-run hard kill of host 0, then coordinated resume ----------
        rounds = max(4, args.rounds)
        die_at = max(2, rounds // 2)
        exch2 = os.path.join(tmp, "exchange_kill")
        ckpt = os.path.join(tmp, "ckpt")
        # survivors burn one full exchange timeout detecting the dead
        # peer (crash-stop detection); cap it — rounds complete in
        # seconds, so 60s is still far above live-host skew
        base = ["--executor", "async", "--ckpt", ckpt,
                "--rounds", str(rounds),
                "--timeout", str(min(args.timeout, 60.0)), *fault_flags]
        kill_results = [None] + [os.path.join(tmp, f"kill_host{h}.json")
                                 for h in range(1, args.n_hosts)]
        procs = [_spawn(args, 0, args.n_hosts, exch2,
                        os.path.join(tmp, "kill_host0.json"),
                        extra=[*base, "--die-at-round", str(die_at)])]
        procs += [_spawn(args, h, args.n_hosts, exch2, kill_results[h],
                         extra=base) for h in range(1, args.n_hosts)]
        _collect(procs, kill_results,
                 expect=[17] + [0] * (args.n_hosts - 1))
        restore = _newest_checkpoint_round(ckpt, host=0)
        if restore is None:
            failures.append("killed host left no loadable checkpoint — "
                            "nothing to resume from")
            return cases, failures, hosts[0]["devices"]

        resume_results = [os.path.join(tmp, f"resume_host{h}.json")
                          for h in range(args.n_hosts)]
        resumed = _collect(
            [_spawn(args, h, args.n_hosts, exch2, resume_results[h],
                    extra=[*base, "--resume"])
             for h in range(args.n_hosts)], resume_results)
        if len({r["final_acc"] for r in resumed}) != 1:
            failures.append(f"resumed hosts diverged: final_acc "
                            f"{[r['final_acc'] for r in resumed]}")
        recovery = rounds - restore
        print(f"kill-resume: host 0 killed at round {die_at}, topology "
              f"restored from round {restore} -> {recovery} of {rounds} "
              f"rounds replayed")
        cases.append(dict(common, host="chaos_kill_resume",
                          executor="async", rounds=rounds,
                          faults=resumed[0]["faults"],
                          final_acc=resumed[0]["final_acc"],
                          host_crash_recovery_rounds=recovery))
    return cases, failures, hosts[0]["devices"]


if __name__ == "__main__":
    raise SystemExit(main())
