"""Benchmark harness — one module per paper table + the roofline reporter.

    table3_cv            Tab. 3  CV accuracy × data heterogeneity (α sweep)
    table4_nlp           Tab. 4  NLP fine-tuning accuracy
    table5_participation Tab. 5  participation-ratio sweep (C)
    table6_rounds        Tab. 6  accuracy at communication-round checkpoints
    table7_buffer        Tab. 7/8 buffer-length (M) ablation
    table9_losstype      Tab. 9  KL vs MSE regularizer
    kernel_bench         kernel HBM-traffic + wall-time microbench
    roofline             §Roofline term table from dry-run JSON

``python -m benchmarks.run`` executes the fast preset of every table and
prints ``name,us_per_call,derived`` CSV (plus per-table accuracy CSVs).
"""
