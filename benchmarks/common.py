"""Shared benchmark harness: run a set of FL algorithms on a task and report
mean±std over trials (the paper reports 3 trials; presets below default to
fewer for CPU budget — pass --trials to match)."""
from __future__ import annotations

import time

import numpy as np

from repro.configs.paper import PaperTask, scaled
from repro.core import algorithms, fl_loop

# paper hyper-parameters per method (Section 5.1 "Parameter Setting")
def make_algo(name: str, task: PaperTask, *, buffer_m: int | None = None,
              loss_type: str = "kl"):
    gamma = task.gamma
    m = buffer_m if buffer_m is not None else task.buffer_m
    mu_prox = 0.01 if task.name == "cifar10" else 0.001
    mu_moon = {"cifar10": 5.0, "cifar100": 5.0, "tiny-imagenet": 1.0}.get(
        task.name, 0.1)
    table = {
        "fedavg": lambda: algorithms.make("fedavg"),
        "fedprox": lambda: algorithms.make("fedprox", mu=mu_prox),
        "moon": lambda: algorithms.make("moon", mu=mu_moon, tau=0.5),
        "feddistill+": lambda: algorithms.make("feddistill+", beta=0.1),
        "fedgen": lambda: algorithms.make("fedgen", alpha=1.0, gen_steps=20),
        "fedgkd": lambda: algorithms.make("fedgkd", gamma=gamma, buffer_m=m,
                                          loss_type=loss_type),
        "fedgkd-vote": lambda: algorithms.make("fedgkd-vote", gamma=gamma,
                                               buffer_m=m),
        "fedgkd+": lambda: algorithms.make("fedgkd+", gamma=gamma, buffer_m=m),
    }
    return table[name]()


def run_methods(task: PaperTask, methods: list[str], alphas: list[float], *,
                trials: int = 1, n_test: int = 400, scale: float = 0.04,
                rounds: int | None = None, local_epochs: int | None = None,
                max_batches: int | None = None, width: int = 16,
                buffer_m: int | None = None, verbose: bool = False,
                executor: str = "auto"):
    """Returns rows: dicts with method, alpha, best, final, std, seconds."""
    t = scaled(task, scale, rounds=rounds, local_epochs=local_epochs)
    rows = []
    for alpha in alphas:
        datas = [fl_loop.make_federated_data(t, alpha=alpha, seed=s,
                                             n_test=n_test)
                 for s in range(trials)]
        for name in methods:
            best, final, secs = [], [], []
            for s in range(trials):
                algo = make_algo(name, t, buffer_m=buffer_m)
                t0 = time.time()
                h = fl_loop.run_federated(t, algo, datas[s], seed=s,
                                          max_batches_per_client=max_batches,
                                          verbose=verbose, executor=executor)
                secs.append(time.time() - t0)
                best.append(h.best_acc)
                final.append(h.final_acc)
            rows.append({
                "task": t.name, "method": name, "alpha": alpha,
                "best_mean": float(np.mean(best)), "best_std": float(np.std(best)),
                "final_mean": float(np.mean(final)),
                "final_std": float(np.std(final)),
                "seconds": float(np.mean(secs)),
                "history": h.accs(),
            })
            print(f"  {t.name} α={alpha} {name:12s} "
                  f"best={np.mean(best):.4f}±{np.std(best):.4f} "
                  f"final={np.mean(final):.4f} ({np.mean(secs):.0f}s)",
                  flush=True)
    return rows


def csv_rows(rows: list[dict], keys: list[str]) -> str:
    out = [",".join(keys)]
    for r in rows:
        out.append(",".join(str(r.get(k, "")) for k in keys))
    return "\n".join(out)
