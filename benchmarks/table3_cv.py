"""Paper Table 3: CV top-1 accuracy × heterogeneity (α ∈ {1, 0.5, 0.1}).

Synthetic CIFAR-10/100-class stand-ins (DESIGN.md §8): the comparison is
method-vs-method ordering, validating the paper's claims that FedGKD(-VOTE/+)
lead under non-IID skew.
"""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, run_methods
from repro.configs.paper import CIFAR10, CIFAR100

METHODS = ["fedavg", "fedprox", "moon", "feddistill+", "fedgen",
           "fedgkd", "fedgkd-vote", "fedgkd+"]


def run(preset: str = "fast"):
    cfgs = {
        # (scale, rounds, local_epochs, trials, alphas, tasks, methods)
        "fast": dict(scale=0.02, rounds=3, local_epochs=1, trials=1,
                     alphas=[0.1], tasks=[CIFAR10],
                     methods=["fedavg", "fedgkd"]),
        "medium": dict(scale=0.05, rounds=8, local_epochs=2, trials=2,
                       alphas=[1.0, 0.5, 0.1], tasks=[CIFAR10],
                       methods=METHODS),
        "full": dict(scale=0.1, rounds=15, local_epochs=3, trials=3,
                     alphas=[1.0, 0.5, 0.1], tasks=[CIFAR10, CIFAR100],
                     methods=METHODS),
    }[preset]
    rows = []
    for task in cfgs["tasks"]:
        rows += run_methods(task, cfgs["methods"], cfgs["alphas"],
                            trials=cfgs["trials"], scale=cfgs["scale"],
                            rounds=cfgs["rounds"],
                            local_epochs=cfgs["local_epochs"])
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="medium",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    rows = run(args.preset)
    print(csv_rows(rows, ["task", "method", "alpha", "best_mean", "best_std",
                          "final_mean", "seconds"]))


if __name__ == "__main__":
    main()
