"""Async-vs-sync simulated wall-clock-to-target-accuracy benchmark.

The synchronous executors end every round at the barrier — the slowest
sampled client.  Under a straggler tail that barrier dominates: this bench
puts a configurable tail (default: 20% of clients at 4x slowdown) under
the ``toy`` preset, runs the synchronous baseline for ``--rounds`` rounds,
replays its per-round barrier cost on the same seeded virtual clock
(``repro.core.systemsim``), then measures how much simulated wall-clock
the buffered-async executor needs to reach the SAME accuracy.

Writes ``BENCH_async.json`` at the repo root — the artifact
``benchmarks/compare_bench.py`` gates the nightly job on (metric:
``sim_speedup_vs_sync``, bigger is better).  The acceptance criterion from
the async-rounds issue — async reaches the sync round-10 accuracy in
<= 0.6x the simulated clock — is enforced directly via ``--max-ratio``:

    PYTHONPATH=src python benchmarks/async_bench.py                # default
    PYTHONPATH=src python benchmarks/async_bench.py --algos fedgkd \
        --buffer 4 --straggler-frac 0.2 --straggler-slowdown 4
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax

from repro.configs.paper import PAPER_TASKS
from repro.core import algorithms, fl_loop
from repro.core.executor import AsyncExecutor
from repro.core.systemsim import SpeedProfile, SystemSim, derive_rng
from repro.data.pipeline import num_batches

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def sync_sim_clock(records, sim: SystemSim, work) -> list[float]:
    """Cumulative synchronous virtual clock: each round costs the barrier
    max over its sampled cohort's durations."""
    out, t = [], 0.0
    for rec in records:
        t += max(sim.duration(k, work[k]) for k in rec.sampled)
        out.append(t)
    return out


def bench_algo(algo_name: str, task, data, args) -> dict:
    profile = SpeedProfile(kind="straggler",
                           straggler_frac=args.straggler_frac,
                           straggler_slowdown=args.straggler_slowdown)
    work = [num_batches(c.n, task.batch_size, task.local_epochs)
            for c in data.clients]
    mk = lambda: algorithms.make(algo_name, **(
        {"buffer_m": args.buffer_m} if algo_name.startswith("fedgkd") else {}))

    hs = fl_loop.run_federated(task, mk(), data, rounds=args.rounds,
                               seed=args.seed, executor="vmap")
    sim = SystemSim(data.n_clients, profile, rng=derive_rng(args.seed))
    sync_clock = sync_sim_clock(hs.records, sim, work)
    target = hs.records[-1].test_acc

    scheme = "fedgkd" if algo_name.startswith("fedgkd") else "polynomial"
    ha = fl_loop.run_federated(
        task, mk(), data, rounds=args.rounds * args.async_rounds_mult,
        seed=args.seed,
        executor=AsyncExecutor(buffer_size=args.buffer, staleness=scheme,
                               profile=profile))
    hit = next((r for r in ha.records if r.test_acc >= target), None)

    row = {"algo": algo_name, "executor": "async",
           "epochs": task.local_epochs, "precompute": True,
           "buffer_size": args.buffer, "staleness_scheme": scheme,
           "profile": profile.kind,
           "straggler_frac": args.straggler_frac,
           "straggler_slowdown": args.straggler_slowdown,
           "target_acc": round(target, 4),
           "sync_rounds": args.rounds,
           "sync_sim_clock": round(sync_clock[-1], 2),
           "async_best_acc": round(ha.best_acc, 4),
           "mean_staleness": round(ha.telemetry["mean_staleness"], 3),
           "max_staleness": ha.telemetry["max_staleness"],
           "stale_absorbed": ha.telemetry["stale_absorbed"]}
    if hit is None:
        row.update(reached=False, sim_speedup_vs_sync=0.0)
    else:
        row.update(reached=True,
                   aggregations_to_target=hit.round,
                   async_sim_clock=round(hit.sim_time, 2),
                   clock_ratio=round(hit.sim_time / sync_clock[-1], 4),
                   sim_speedup_vs_sync=round(sync_clock[-1] / hit.sim_time,
                                             4))
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="toy", choices=sorted(PAPER_TASKS))
    ap.add_argument("--algos", nargs="+", default=["fedavg", "fedgkd"])
    ap.add_argument("--rounds", type=int, default=10,
                    help="sync baseline rounds; the target is the sync "
                         "accuracy after this many rounds")
    ap.add_argument("--async-rounds-mult", type=int, default=4,
                    dest="async_rounds_mult",
                    help="async aggregation budget as a multiple of "
                         "--rounds (each aggregation applies only B "
                         "updates, so async needs more of them)")
    ap.add_argument("--buffer", type=int, default=4,
                    help="async aggregation buffer B")
    ap.add_argument("--buffer-m", type=int, default=3,
                    help="FedGKD teacher buffer M")
    ap.add_argument("--straggler-frac", type=float, default=0.2)
    ap.add_argument("--straggler-slowdown", type=float, default=4.0)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-ratio", type=float, default=0.6,
                    help="fail if async needs more than this fraction of "
                         "the sync simulated clock (the acceptance "
                         "criterion); 0 disables the gate")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_async.json"))
    args = ap.parse_args(argv)

    task = PAPER_TASKS[args.task]
    data = fl_loop.make_federated_data(task, alpha=args.alpha, seed=0,
                                       n_test=400)
    n_sample = max(1, int(round(task.participation * data.n_clients)))

    cases = []
    for algo_name in args.algos:
        row = bench_algo(algo_name, task, data, args)
        cases.append(row)
        if row["reached"]:
            print(f"{algo_name:>12}: sync acc {row['target_acc']:.4f} at "
                  f"sim t={row['sync_sim_clock']:.0f}; async reached it at "
                  f"t={row['async_sim_clock']:.0f} "
                  f"({row['clock_ratio']:.2f}x, speedup "
                  f"{row['sim_speedup_vs_sync']:.2f}x)")
        else:
            print(f"{algo_name:>12}: async best {row['async_best_acc']:.4f} "
                  f"< target {row['target_acc']:.4f} — NOT reached")

    payload = {"task": args.task, "devices": len(jax.devices()),
               "backend": jax.default_backend(), "clients": n_sample,
               "width": 16, "buffer": args.buffer,
               "profile": "straggler", "cases": cases}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")

    if args.max_ratio > 0:
        bad = [c for c in cases if not c["reached"]
               or c["clock_ratio"] > args.max_ratio]
        if bad:
            print(f"FAIL: {len(bad)} case(s) missed the <= "
                  f"{args.max_ratio:.1f}x simulated-clock criterion: "
                  f"{[c['algo'] for c in bad]}")
            return 1
        print(f"all cases within {args.max_ratio:.1f}x of the sync clock")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
