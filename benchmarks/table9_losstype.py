"""Paper Table 9: regularizer ablation — None (FedAvg) vs MSE vs KL, M=1."""
from __future__ import annotations

import argparse


from benchmarks.common import csv_rows
from repro.configs.paper import CIFAR10, scaled
from repro.core import algorithms, fl_loop


def run(preset: str = "fast"):
    cfgs = {
        "fast": dict(scale=0.02, rounds=3, epochs=1),
        "medium": dict(scale=0.05, rounds=10, epochs=2),
        "full": dict(scale=0.1, rounds=20, epochs=3),
    }[preset]
    task = scaled(CIFAR10, cfgs["scale"], rounds=cfgs["rounds"],
                  local_epochs=cfgs["epochs"])
    data = fl_loop.make_federated_data(task, alpha=0.1, seed=0, n_test=400)
    rows = []
    for loss_type in ("none", "mse", "kl"):
        if loss_type == "none":
            algo = algorithms.make("fedavg")
        else:
            algo = algorithms.make("fedgkd", gamma=task.gamma, buffer_m=1,
                                   loss_type=loss_type)
        h = fl_loop.run_federated(task, algo, data, seed=0)
        rows.append({"loss_type": loss_type, "best": h.best_acc,
                     "final": h.final_acc})
        print(f"  loss={loss_type:5s} best={h.best_acc:.4f} "
              f"final={h.final_acc:.4f}", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="medium",
                    choices=("fast", "medium", "full"))
    args = ap.parse_args()
    rows = run(args.preset)
    print(csv_rows(rows, ["loss_type", "best", "final"]))


if __name__ == "__main__":
    main()
