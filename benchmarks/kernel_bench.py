"""Kernel microbench: wall-time of the jnp reference paths (the CPU-hosted
execution path) + analytic HBM-traffic savings of the Pallas kernels at the
assigned architectures' real dimensions.

Wall-clock here is CPU (interpret mode is not representative of TPU); the
derived column is the kernel's HBM byte ratio vs the reference — the
quantity that governs the TPU memory-roofline term.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.kd_kl import ref as kd_ref
from repro.models import ssm


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def kd_kl_traffic_ratio(t: int, v: int) -> float:
    """ref: read lt+ls, write p_t & two log-softmaxes (≥3 extra tensors).
    kernel: read lt+ls once.  ratio = kernel/ref bytes."""
    ref_bytes = (2 + 3) * t * v * 4
    kern_bytes = 2 * t * v * 4
    return kern_bytes / ref_bytes


def run(preset: str = "fast"):
    rows = []
    sizes = {"fast": [(256, 32_000)], "medium": [(256, 32_000), (512, 129_280)],
             "full": [(256, 32_000), (512, 129_280), (1024, 256_206)]}[preset]
    for t, v in sizes:
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        lt = jax.random.normal(k1, (t, v))
        ls = jax.random.normal(k2, (t, v))
        f = jax.jit(lambda a, b: jnp.mean(kd_ref.kd_kl_rowwise(a, b)))
        us = _time(f, lt, ls)
        rows.append({"name": f"kd_kl_ref_T{t}_V{v}", "us_per_call": us,
                     "derived": f"traffic_ratio={kd_kl_traffic_ratio(t, v):.3f}"})

    # SSD chunked scan (the Mamba2 hot path) at mamba2-2.7b head geometry
    for l in {"fast": [512], "medium": [512, 2048], "full": [512, 2048, 8192]}[preset]:
        b, h, p, g, n = 1, 8, 64, 1, 128
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (b, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B = jax.random.normal(ks[3], (b, l, g, n))
        C = jax.random.normal(ks[4], (b, l, g, n))
        f = jax.jit(lambda *a: ssm.ssd_chunked(*a, chunk=256)[0])
        us = _time(f, x, dt, A, B, C)
        seq_f = jax.jit(lambda *a: ssm.ssd_reference(*a))
        us_seq = _time(seq_f, x, dt, A, B, C, iters=1)
        rows.append({"name": f"ssd_chunked_L{l}", "us_per_call": us,
                     "derived": f"seq_scan_us={us_seq:.0f}"})
    return rows


def main():
    for r in run("medium"):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
