"""DP hook: clipping bounds deltas; noise has the configured scale;
FedGKD runs under DP end-to-end (the paper's compatibility claim)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy
from repro.optim import global_norm
from proptest import sweep


@sweep(n=8)
def test_clip_bounds_delta(rng):
    anchor = {"w": jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)}
    new = {"w": anchor["w"] + jnp.asarray(
        rng.standard_normal((6, 4)) * rng.uniform(0.1, 10), jnp.float32)}
    c = float(rng.uniform(0.1, 2.0))
    clipped = privacy.clip_delta(new, anchor, c)
    delta_norm = float(global_norm(jax.tree_util.tree_map(
        lambda a, b: a - b, clipped, anchor)))
    assert delta_norm <= c * 1.001


def test_clip_is_identity_inside_ball():
    anchor = {"w": jnp.zeros((4,))}
    new = {"w": jnp.asarray([0.1, 0.0, 0.0, 0.0])}
    out = privacy.clip_delta(new, anchor, clip_norm=1.0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(new["w"]),
                               atol=1e-7)


def test_noise_scale():
    params = {"w": jnp.zeros((2000,))}
    noised = privacy.add_noise(params, std=0.5, rng=jax.random.PRNGKey(0))
    emp = float(jnp.std(noised["w"]))
    assert abs(emp - 0.5) < 0.05


def test_fedgkd_runs_under_dp():
    from repro.configs.paper import CIFAR10, scaled
    from repro.core import algorithms, fl_loop
    task = scaled(CIFAR10, 0.01, rounds=2, local_epochs=1)
    data = fl_loop.make_federated_data(task, alpha=0.5, seed=0, n_test=80)
    dp = privacy.DPConfig(clip_norm=5.0, noise_multiplier=0.1)
    h = fl_loop.run_federated(task, algorithms.make("fedgkd", buffer_m=2),
                              data, seed=0, max_batches_per_client=2, dp=dp)
    assert np.isfinite(h.final_acc)
