"""Client-batched grouped convolution (kernels/grouped_conv).

Semantics oracle is the NAIVE per-client path — ``jax.vmap`` of a plain
``conv_general_dilated`` — which is exactly what the batched executors
historically lowered to.  The grouped rewrite must match it in value AND in
both gradients (the custom VJP replaces autodiff), across strides, SAME and
VALID padding, 1x1 projections, and masked (ragged) rows.  The Pallas
im2col kernel runs in interpret mode here (CI has no TPU); the ``kernels``
CI job executes this file under two jax versions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_conv import kernel as gk
from repro.kernels.grouped_conv import ops, ref

CASES = [
    # (K, N, H, Cin, Cout, kh, stride, padding)
    (4, 3, 12, 8, 8, 3, 1, "SAME"),
    (4, 3, 12, 8, 16, 3, 2, "SAME"),          # strided downsample
    (3, 2, 9, 4, 8, 1, 2, "SAME"),            # 1x1 projection, stride 2
    (2, 2, 10, 4, 4, 3, 1, "VALID"),
    (2, 2, 11, 4, 4, 3, 2, "VALID"),          # VALID + non-dividing stride
]


def _case(seed, K, N, H, Cin, Cout, kh, *_):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (K, N, H, H, Cin))
    w = jax.random.normal(k2, (K, kh, kh, Cin, Cout)) * 0.1
    return x, w


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"k{c[5]}s{c[6]}{c[7]}")
def test_forward_matches_naive_vmap(case):
    K, N, H, Cin, Cout, kh, s, pad = case
    x, w = _case(0, *case)
    want = ref.naive_vmap_conv(x, w, s, pad)
    got = ops.client_batched_conv(x, w, stride=s, padding=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"k{c[5]}s{c[6]}{c[7]}")
def test_pallas_forward_matches_oracle(case):
    K, N, H, Cin, Cout, kh, s, pad = case
    x, w = _case(1, *case)
    want = ref.grouped_pack_conv(x, w, s, pad)
    got = ops.client_batched_conv(x, w, stride=s, padding=pad,
                                  use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"k{c[5]}s{c[6]}{c[7]}")
def test_gradients_match_naive_vmap(case):
    """dx AND dw of the custom VJP against autodiff of the vmapped conv."""
    K, N, H, Cin, Cout, kh, s, pad = case
    x, w = _case(2, *case)
    dy_key = jax.random.PRNGKey(3)

    def f(x, w):
        out = ops.client_batched_conv(x, w, stride=s, padding=pad)
        return jnp.mean(out * jax.random.normal(dy_key, out.shape))

    def f_ref(x, w):
        out = ref.naive_vmap_conv(x, w, s, pad)
        return jnp.mean(out * jax.random.normal(dy_key, out.shape))

    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    dx_r, dw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r), atol=1e-5)


def test_gradients_with_ragged_masked_rows():
    """Zero-weighted (padded) examples must contribute nothing to dw, and
    their own dx rows must be exactly zero — the executor padding contract."""
    K, N, H, Cin, Cout, kh, s = 3, 4, 8, 4, 4, 3, 1
    x, w = _case(4, K, N, H, Cin, Cout, kh)
    mask = jnp.asarray([[1, 1, 1, 1], [1, 1, 0, 0], [1, 0, 0, 0]],
                       jnp.float32)                     # ragged clients

    def masked_loss(conv):
        def f(x, w):
            out = conv(x, w)
            per_ex = jnp.mean(out * out, axis=(2, 3, 4))     # (K, N)
            return jnp.sum(per_ex * mask) / jnp.sum(mask)
        return f

    f = masked_loss(lambda x, w: ops.client_batched_conv(x, w, stride=s))
    f_ref = masked_loss(lambda x, w: ref.naive_vmap_conv(x, w, s))
    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    dx_r, dw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), atol=1e-5)
    # masked rows get exactly zero input gradient
    assert float(jnp.abs(dx[1, 2:]).max()) == 0.0
    assert float(jnp.abs(dx[2, 1:]).max()) == 0.0


def test_gradients_under_jit_and_second_application():
    """The custom VJP must survive jit and repeated application (the
    executor calls it once per conv per step inside one jitted round)."""
    case = CASES[0]
    K, N, H, Cin, Cout, kh, s, pad = case
    x, w = _case(5, *case)

    @jax.jit
    def two_layer(x, w):
        h = jax.nn.relu(ops.client_batched_conv(x, w, stride=s, padding=pad))
        return jnp.mean(ops.client_batched_conv(h, w, stride=s,
                                                padding=pad) ** 2)

    @jax.jit
    def two_layer_ref(x, w):
        h = jax.nn.relu(ref.naive_vmap_conv(x, w, s, pad))
        return jnp.mean(ref.naive_vmap_conv(h, w, s, pad) ** 2)

    dw = jax.grad(two_layer, argnums=1)(x, w)
    dw_r = jax.grad(two_layer_ref, argnums=1)(x, w)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r), atol=1e-5)


def test_kernel_direct_call():
    """The Pallas kernel itself (pre-padded input, VALID semantics)."""
    K, N, H, Cin, Cout, kh, s = 2, 2, 10, 4, 8, 3, 1
    x, w = _case(6, K, N, H, Cin, Cout, kh)
    oh = H - kh + 1
    out = gk.grouped_conv_fwd(x, w, stride=s, oh=oh, ow=oh, interpret=True)
    want = ref.naive_vmap_conv(x, w, s, "VALID")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_shape_validation():
    x = jnp.zeros((2, 2, 8, 8, 4))
    w = jnp.zeros((2, 3, 3, 4, 4))
    with pytest.raises(ValueError, match="wants x"):
        ops.client_batched_conv(x[0], w)
    with pytest.raises(ValueError, match="client axes disagree"):
        ops.client_batched_conv(x, jnp.zeros((3, 3, 3, 4, 4)))
    with pytest.raises(ValueError, match="padding"):
        ops.client_batched_conv(x, w, padding="FULL")


def test_resnet_conv_dispatches_on_stacked_weights():
    """models.resnet.conv: 4-D weights -> plain lax conv (bitwise identical
    to the historical path), 5-D weights -> the client-batched kernel."""
    from repro.models import resnet
    K, B = 3, 2
    keys = jax.random.split(jax.random.PRNGKey(7), K)
    params = [resnet.conv_init(k, 3, 3, 4, 8) for k in keys]
    x = jax.random.normal(jax.random.PRNGKey(8), (K, B, 8, 8, 4))
    single = jnp.stack([resnet.conv(p, x[i], stride=2)
                        for i, p in enumerate(params)])
    stacked = {"w": jnp.stack([p["w"] for p in params])}
    batched = resnet.conv(stacked, x, stride=2)
    np.testing.assert_allclose(np.asarray(batched), np.asarray(single),
                               atol=1e-6)
