"""End-to-end behaviour: the paper's headline claim on a miniature setup.

FedGKD must not lose to FedAvg under strong non-IID (α=0.1) — this is the
paper's central empirical claim (Tab. 3), checked at a CPU-friendly scale
with multiple seeds for stability.
"""
import numpy as np
import pytest

from repro.configs.paper import CIFAR10, scaled
from repro.core import algorithms, fl_loop


@pytest.mark.slow
def test_fedgkd_not_worse_than_fedavg_noniid():
    task = scaled(CIFAR10, scale=0.04, rounds=6, local_epochs=2)
    best = {"fedavg": [], "fedgkd": []}
    for seed in (0, 1):
        data = fl_loop.make_federated_data(task, alpha=0.1, seed=seed,
                                           n_test=400)
        for name in best:
            algo = (algorithms.make("fedgkd", gamma=0.2, buffer_m=5)
                    if name == "fedgkd" else algorithms.make("fedavg"))
            h = fl_loop.run_federated(task, algo, data, seed=seed)
            best[name].append(h.best_acc)
    avg_fedavg = float(np.mean(best["fedavg"]))
    avg_fedgkd = float(np.mean(best["fedgkd"]))
    # allow noise, but FedGKD must be at least competitive
    assert avg_fedgkd >= avg_fedavg - 0.03, best
