"""§Perf optimization levers must be semantics-preserving:
sharding constraints, batched MoE groups, last-only prefill, cached-top-K KD.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.models import moe as moe_lib, transformer


def test_moe_constraints_preserve_values():
    """dp/ep sharding constraints are no-ops numerically (1-device mesh)."""
    cfg = moe_lib.MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                            group_size=16, capacity_factor=2.0)
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    base, _ = moe_lib.moe_apply(params, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        con, _ = jax.jit(lambda p, x: moe_lib.moe_apply(
            p, x, cfg._replace(dp_axis="data", ep_axis="model")))(params, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(con),
                               rtol=1e-5, atol=1e-5)


def test_moe_batched_groups_match_scan():
    cfg = moe_lib.MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                            group_size=16, capacity_factor=2.0)
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    a, aux_a = moe_lib.moe_apply(params, x, cfg)
    b, aux_b = moe_lib.moe_apply(params, x, cfg._replace(batched_groups=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(float(aux_a), float(aux_b), rtol=1e-5)


def test_prefill_last_only_matches_full():
    cfg = configs.get_smoke_config("phi4-mini-3.8b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    full = steps_lib.make_prefill_step(cfg)(params, batch)
    last = steps_lib.make_prefill_step(cfg, last_only=True)(params, batch)
    assert last.shape == (2, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_unrolled_layers_match_scan():
    """The cost-probe execution mode (scan_layers=False) is numerically
    identical to the production scan mode."""
    for arch in ("phi4-mini-3.8b", "mixtral-8x7b", "zamba2-1.2b",
                 "deepseek-v3-671b"):
        cfg = configs.get_smoke_config(arch)
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                  cfg.vocab_size)
        a, _ = transformer.forward(params, cfg, toks)
        b, _ = transformer.forward(params, cfg.replace(scan_layers=False), toks)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4), arch


def test_unrolled_decode_matches_scan_decode():
    cfg = configs.get_smoke_config("mixtral-8x7b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((1, 1), jnp.int32)
    cache_a = transformer.init_cache(cfg, 1, 8, jnp.float32)
    cache_b = transformer.init_cache(cfg.replace(scan_layers=False), 1, 8,
                                     jnp.float32)
    la, _ = transformer.decode_step(params, cfg, tok, cache_a)
    lb, _ = transformer.decode_step(params, cfg.replace(scan_layers=False),
                                    tok, cache_b)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-4, atol=2e-4)


def test_cached_topk_step_runs_and_reduces_work():
    """cached_topk train step: runs, finite, and its loss ~ KD-teacher loss
    when top-K covers the whole (small) vocab."""
    cfg = configs.get_smoke_config("phi4-mini-3.8b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    teacher = transformer.init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 8
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                                     cfg.vocab_size),
    }
    t_logits, _ = transformer.forward(teacher, cfg, batch["tokens"])
    vals, idx = jax.lax.top_k(t_logits, cfg.vocab_size)
    batch_ck = dict(batch, teacher_topk_vals=vals, teacher_topk_idx=idx)

    loss_t = steps_lib.make_loss_fn(cfg, kd_mode="teacher", gamma=0.2)
    loss_c = steps_lib.make_loss_fn(cfg, kd_mode="cached_topk", gamma=0.2)
    lt, mt = loss_t(params, teacher, batch)
    lc, mc = loss_c(params, (), batch_ck)
    np.testing.assert_allclose(float(lt), float(lc), rtol=1e-4)
