"""Sharding rules + a reduced-mesh dry-run (lower+compile) in a subprocess
with a forced host device count (the main pytest process stays at 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs, sharding as sh
from repro.models import transformer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_col_row_assignment():
    cfg = configs.get_smoke_config("phi4-mini-3.8b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    specs = sh.param_specs(params, cfg)
    # stacked layer weights: leading None + col/row split
    assert specs["seg0"]["attn"]["wq"]["w"] == P(None, None, "model")
    assert specs["seg0"]["attn"]["wo"]["w"] == P(None, "model", None)
    assert specs["seg0"]["mlp"]["gate"]["w"] == P(None, None, "model")
    assert specs["seg0"]["mlp"]["down"]["w"] == P(None, "model", None)
    assert specs["embed"]["table"] == P("model", None)
    assert specs["final_norm"]["scale"] == P(None)


def test_param_specs_fsdp_adds_data_axis():
    cfg = configs.get_smoke_config("phi4-mini-3.8b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    specs = sh.param_specs(params, cfg, fsdp=True)
    assert specs["seg0"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["embed"]["table"] == P("model", "data")


def test_moe_expert_parallel_spec():
    cfg = configs.get_smoke_config("mixtral-8x7b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    specs = sh.param_specs(params, cfg)
    assert specs["seg0"]["moe"]["gate"][0] if False else True
    moe = specs["seg0"]["moe"]
    assert moe["gate"] == P(None, "model", None, None)   # (L, E, D, F)
    assert moe["down"] == P(None, "model", None, None)
    assert moe["router"]["w"] == P(None, None, None)


def test_make_mesh_compat_matches_make_mesh():
    """The version shim must produce the same mesh jax.make_mesh would
    (and still work if jax.make_mesh is absent, via the mesh_utils path)."""
    m = sh.make_mesh_compat((1,), ("clients",))
    assert m.axis_names == ("clients",)
    assert m.devices.shape == (1,)
    m2 = sh.make_mesh_compat((1, 1), ("data", "model"))
    assert m2.axis_names == ("data", "model")


def test_make_clients_mesh_spans_all_devices():
    from repro.launch.mesh import make_clients_mesh
    mesh = make_clients_mesh()
    assert mesh.axis_names == ("clients",)
    assert mesh.devices.size == len(jax.devices())
    explicit = make_clients_mesh(1)
    assert explicit.devices.size == 1


def test_fit_specs_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("model",))
    spec = sh.fit_specs(P("model"), jax.ShapeDtypeStruct((7,), jnp.float32),
                        mesh)
    assert spec == P("model")  # axis size 1 divides everything
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    spec = sh.fit_specs(P(("data", "model"), None),
                        jax.ShapeDtypeStruct((3, 4), jnp.float32), mesh2)
    assert spec == P(("data", "model"), None)


_DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import warnings; warnings.filterwarnings("ignore")
    import jax, json
    from repro.launch import dryrun_lib
    dryrun_lib.make_production_mesh = lambda multi_pod=False: (
        jax.make_mesh((2,2,4), ("pod","data","model")) if multi_pod
        else jax.make_mesh((4,4), ("data","model")))
    results = []
    for arch, shape, multi in %s:
        r = dryrun_lib.run_dryrun(arch, shape, multi_pod=multi)
        results.append({"arch": arch, "shape": shape, "ok": r.ok,
                        "err": r.error, "flops": r.flops})
    print("JSON:" + json.dumps(results))
""")


def _run_subprocess(pairs):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _DRYRUN_SNIPPET % repr(pairs)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")][-1]
    return json.loads(line[5:])


@pytest.mark.slow
def test_dryrun_reduced_mesh_lowers_and_compiles():
    """3 representative (arch × shape) pairs + one multi-pod, on a 16-device
    stand-in mesh: lower().compile() must succeed and report nonzero FLOPs."""
    pairs = [("phi4-mini-3.8b", "decode_32k", False),
             ("mixtral-8x7b", "train_4k", False),
             ("mamba2-2.7b", "long_500k", False),
             ("phi4-mini-3.8b", "train_4k", True)]
    for r in _run_subprocess(pairs):
        assert r["ok"], (r["arch"], r["shape"], r["err"])
        assert r["flops"] > 0


@pytest.mark.slow
def test_sharded_fl_driver_runs():
    """shard_map clients-parallel FL round on 4 host devices."""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "phi4-mini-3.8b", "--smoke", "--rounds", "1",
         "--batches-per-round", "2", "--batch", "2", "--seq", "16",
         "--sharded"],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "final ppl" in out.stdout
