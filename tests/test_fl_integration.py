"""Integration: full federated rounds for every algorithm on tiny synthetic
data (image + text), plus the system-level behaviours the paper reports."""
import numpy as np
import pytest

from repro.configs.paper import CIFAR10, SST5, scaled
from repro.core import algorithms, fl_loop


@pytest.fixture(scope="module")
def small_setup():
    task = scaled(CIFAR10, scale=0.01, rounds=2, local_epochs=1)
    data = fl_loop.make_federated_data(task, alpha=0.5, seed=0, n_test=120)
    return task, data


@pytest.mark.parametrize("name", algorithms.available())
def test_every_algorithm_runs(small_setup, name):
    task, data = small_setup
    algo = algorithms.make(name)
    h = fl_loop.run_federated(task, algo, data, seed=0,
                              max_batches_per_client=2)
    assert len(h.records) == 2
    assert np.isfinite(h.final_acc)
    assert 0.0 <= h.final_acc <= 1.0
    assert np.isfinite(h.records[-1].mean_local_loss)


def test_text_task_runs():
    task = scaled(SST5, scale=0.1, rounds=1, local_epochs=1)
    data = fl_loop.make_federated_data(task, alpha=0.1, seed=0, n_test=60)
    h = fl_loop.run_federated(task, algorithms.make("fedgkd", buffer_m=2),
                              data, seed=0, max_batches_per_client=2)
    assert np.isfinite(h.final_acc)


def test_fedgkd_buffer_tracks_rounds(small_setup):
    task, data = small_setup
    algo = algorithms.make("fedgkd", buffer_m=5)
    h = fl_loop.run_federated(task, algo, data, seed=0,
                              max_batches_per_client=1)
    assert np.isfinite(h.final_acc)
    assert len(h.records) == task.rounds


@pytest.mark.slow
def test_learning_happens_with_more_rounds():
    """With enough data/rounds the global model must beat chance (10%)."""
    task = scaled(CIFAR10, scale=0.05, rounds=4, local_epochs=2)
    data = fl_loop.make_federated_data(task, alpha=100.0, seed=0, n_test=300)
    h = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0)
    assert h.best_acc > 0.15, f"fedavg stuck at {h.best_acc}"


def test_learning_happens_toy_task():
    """Fast learning check (MLP task, batched executor): beat chance (10%)
    by a wide margin within 3 rounds."""
    from repro.configs.paper import TOY
    data = fl_loop.make_federated_data(TOY, alpha=10.0, seed=0, n_test=400)
    h = fl_loop.run_federated(TOY, algorithms.make("fedavg"), data, seed=0,
                              rounds=3, executor="vmap")
    assert h.best_acc > 0.3, f"fedavg stuck at {h.best_acc}"


def test_dirichlet_partition_used(small_setup):
    task, data = small_setup
    assert data.label_matrix.shape == (task.n_clients, task.num_classes)
    assert data.label_matrix.sum() == task.train_size
