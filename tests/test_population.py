"""Population tier: three-level client store, hierarchical O(cohort)
sampling, lazy client-state tiers, and the ``run_federated(population=)``
wiring.

The load-bearing guarantees, each pinned here:

  * ``n_shards=1`` reproduces the flat ``rng.choice`` cohort sequence BIT
    for bit — sync loop and ``_run_async`` wave refills — over 50+ rounds;
  * with ``population=`` enabled the equivalence suites' numbers do not
    move (< 1e-5 vs the eager-data run on every executor);
  * peak host residency is bounded by the warm cap, never the population
    (the ``--runslow`` million-client run asserts it via the counters);
  * pinned (in-flight) clients survive warm/hot/state eviction pressure;
  * ``ClientSlabStore.drop`` keeps the eviction counters truthful.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import sweep
from repro.configs.paper import TOY
from repro.core import algorithms, executor as ex, fl_loop
from repro.core.systemsim import SpeedProfile
from repro.data.pipeline import ClientData, ClientSlabStore, FederatedData
from repro.data.synthetic import SyntheticTabularTask
from repro.population import (DiskShardSource, HierarchicalSampler,
                              InMemorySource, Population,
                              SyntheticClientSource, even_shard_sizes,
                              shift_positions, write_population_shards)
from repro.population.store import ClientStateStore, PopulationStore

RAGGED_SIZES = (20, 45, 64, 100, 130, 150)


def _ragged_data(task, sizes=RAGGED_SIZES):
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(sizes)]
    test_x, test_y = gen.generate(200, seed=999)
    return FederatedData(clients, test_x, test_y,
                         np.zeros((len(sizes), task.num_classes)))


@pytest.fixture(scope="module")
def tiny_setup():
    task = dataclasses.replace(TOY, n_clients=len(RAGGED_SIZES),
                               participation=0.5, batch_size=64, rounds=3,
                               local_epochs=2)
    return task, _ragged_data(task)


def _max_param_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


# --------------------------------------------------------------------------
# hierarchical sampling
# --------------------------------------------------------------------------

def test_even_shard_sizes():
    assert even_shard_sizes(10, 4).tolist() == [4, 4, 2]
    assert even_shard_sizes(8, 4).tolist() == [4, 4]
    assert even_shard_sizes(3, 100).tolist() == [3]
    with pytest.raises(ValueError):
        even_shard_sizes(0, 4)


def test_shift_positions_matches_setdiff_indexing():
    rng = np.random.default_rng(0)
    for _ in range(100):
        n = int(rng.integers(5, 80))
        exc = np.sort(rng.choice(n, size=int(rng.integers(0, 6)),
                                 replace=False))
        survivors = np.setdiff1d(np.arange(n), exc)
        pos = rng.choice(len(survivors),
                         size=min(5, len(survivors)), replace=False)
        np.testing.assert_array_equal(shift_positions(pos, exc),
                                      survivors[pos])


@sweep(n=8)
def test_sampler_draws_distinct_in_range(rng):
    n_shards = int(rng.integers(1, 7))
    sizes = rng.integers(3, 40, size=n_shards)
    s = HierarchicalSampler(sizes)
    k = int(rng.integers(1, min(12, s.n_clients) + 1))
    cohort = s.sample(np.random.default_rng(int(rng.integers(1 << 30))), k)
    assert len(cohort) == k == len(np.unique(cohort))
    assert cohort.min() >= 0 and cohort.max() < s.n_clients


def test_sampler_marginal_is_uniform():
    """Size-weighted shard stage + uniform within-shard stage must give an
    exactly uniform marginal over clients (ragged shards on purpose)."""
    s = HierarchicalSampler([7, 13, 5, 25])
    rng = np.random.default_rng(0)
    counts = np.zeros(s.n_clients)
    draws = 8000
    for _ in range(draws):
        counts[s.sample(rng, 8)] += 1
    p = counts / counts.sum()
    assert np.abs(p - 1.0 / s.n_clients).max() < 0.004


def test_sampler_exclusion_never_leaks_and_stays_uniform():
    s = HierarchicalSampler([7, 13, 5, 25])
    exc = [0, 6, 7, 19, 20, 24, 25, 49]        # shard edges included
    rng = np.random.default_rng(1)
    counts = np.zeros(s.n_clients)
    for _ in range(6000):
        c = s.sample(rng, 8, exclude=exc)
        assert not set(int(i) for i in c) & set(exc)
        counts[c] += 1
    assert (counts[exc] == 0).all()
    p = counts / counts.sum()
    live = np.setdiff1d(np.arange(s.n_clients), exc)
    assert np.abs(p[live] - 1.0 / len(live)).max() < 0.005


def test_sampler_rejection_fast_path_uniform():
    """cohort ≪ population takes the vectorized-rejection path (no
    hypergeometric stage); its marginal must still be exactly uniform."""
    s = HierarchicalSampler([100, 156, 200, 56])          # n = 512
    rng = np.random.default_rng(2)
    counts = np.zeros(s.n_clients)
    for _ in range(20000):
        counts[s.sample(rng, 4)] += 1                     # 4*64 <= 512
    p = counts / counts.sum()
    assert np.abs(p - 1.0 / s.n_clients).max() < 1e-3


def test_sampler_rejection_fast_path_exclusion():
    s = HierarchicalSampler([100, 156, 200, 56])
    exc = [0, 99, 100, 511]
    rng = np.random.default_rng(3)
    counts = np.zeros(s.n_clients)
    for _ in range(15000):
        c = s.sample(rng, 4, exclude=exc)                 # (4+4)*64 == 512
        counts[c] += 1
    assert (counts[exc] == 0).all()
    live = np.setdiff1d(np.arange(s.n_clients), exc)
    p = counts / counts.sum()
    assert np.abs(p[live] - 1.0 / len(live)).max() < 1.2e-3


def test_sampler_single_shard_is_bit_identical_to_flat_choice():
    """The degenerate n_shards=1 draw must consume the generator exactly
    like the historical flat calls — fresh cohorts AND excluded refills."""
    s = HierarchicalSampler([97])
    exc = [5, 50, 96]
    for seed in range(10):
        a, b = np.random.default_rng(seed), np.random.default_rng(seed)
        np.testing.assert_array_equal(
            s.sample(a, 12), b.choice(97, size=12, replace=False))
        idle = np.setdiff1d(np.arange(97), np.asarray(exc))
        np.testing.assert_array_equal(
            s.sample(a, 12, exclude=exc),
            idle[b.choice(len(idle), size=12, replace=False)])


def test_sampler_rejects_oversized_cohort():
    s = HierarchicalSampler([4, 4])
    with pytest.raises(ValueError):
        s.sample(np.random.default_rng(0), 9)
    with pytest.raises(ValueError):
        s.sample(np.random.default_rng(0), 8, exclude=[0])


# --------------------------------------------------------------------------
# sources
# --------------------------------------------------------------------------

def test_synthetic_source_deterministic_and_size_consistent():
    src = SyntheticClientSource(500, seed=3, shard_size=64, min_n=5, max_n=20)
    assert int(src.shard_sizes.sum()) == 500
    for cid in (0, 63, 64, 499):
        c1, c2 = src.client(cid), src.client(cid)
        np.testing.assert_array_equal(c1.x, c2.x)
        np.testing.assert_array_equal(c1.y, c2.y)
        assert src.client_n(cid) == c1.n     # size knowable without arrays
        assert 5 <= c1.n <= 20
    a, b = src.client(7), src.client(8)
    assert not (a.n == b.n and np.array_equal(a.x[:1], b.x[:1]))


def test_disk_shard_source_roundtrip(tmp_path):
    src = SyntheticClientSource(50, seed=1, shard_size=8, min_n=3, max_n=9)
    meta = write_population_shards(
        str(tmp_path), (src.client(i) for i in range(50)), shard_size=16)
    assert meta["n_clients"] == 50
    assert meta["shard_sizes"] == [16, 16, 16, 2]
    disk = DiskShardSource(str(tmp_path), max_open=2)
    rng = np.random.default_rng(0)
    for cid in rng.choice(50, size=20, replace=False):
        want, got = src.client(int(cid)), disk.client(int(cid))
        np.testing.assert_array_equal(want.x, got.x)
        np.testing.assert_array_equal(want.y, got.y)
        assert disk.client_n(int(cid)) == want.n
    assert len(disk._open) <= 2              # handle LRU bounded
    assert disk.shard_opens >= 4             # ...so shards re-opened


def test_disk_shard_source_requires_meta(tmp_path):
    with pytest.raises(FileNotFoundError):
        DiskShardSource(str(tmp_path / "nowhere"))


def _make_source(kind: str, tmp_path):
    if kind == "in_memory":
        gen = SyntheticTabularTask(3, dim=4, seed=0)
        return InMemorySource([ClientData(*gen.generate(6, seed=i))
                               for i in range(12)])
    if kind == "synthetic":
        return SyntheticClientSource(12, seed=0, shard_size=4,
                                     min_n=3, max_n=6)
    src = SyntheticClientSource(12, seed=0, shard_size=4, min_n=3, max_n=6)
    write_population_shards(str(tmp_path),
                            (src.client(i) for i in range(12)), shard_size=4)
    return DiskShardSource(str(tmp_path))


@pytest.mark.parametrize("kind", ["in_memory", "synthetic", "disk"])
def test_sources_reject_out_of_range_client_ids(kind, tmp_path):
    """Satellite regression: ``client(-1)`` must never wrap via negative
    indexing and a source must never mint phantom clients past the
    census — every source raises the same IndexError ``_locate`` does."""
    src = _make_source(kind, tmp_path)
    assert src.n_clients == 12
    for bad in (-1, 12, 10_000):
        with pytest.raises(IndexError, match="out of range"):
            src.client(bad)
        with pytest.raises(IndexError, match="out of range"):
            src.client_n(bad)
    # the boundary ids still work
    assert src.client(0).n == src.client_n(0)
    assert src.client(11).n == src.client_n(11)


def test_disk_max_client_n_goes_through_handle_lru(tmp_path):
    """Satellite regression: ``max_client_n`` must open offset tables
    through the ``_shard`` handle LRU — bounded descriptors, counted
    opens — not ad-hoc ``np.load`` calls outside the cache."""
    src = SyntheticClientSource(20, seed=1, shard_size=4, min_n=3, max_n=9)
    write_population_shards(str(tmp_path),
                            (src.client(i) for i in range(20)), shard_size=4)
    disk = DiskShardSource(str(tmp_path), max_open=2)
    assert disk.shard_opens == 0
    want = max(src.client(i).n for i in range(20))
    assert disk.max_client_n() == want
    assert disk.shard_opens == 5            # every shard's open is counted
    assert len(disk._open) <= 2             # ...and the LRU stayed bounded
    disk.max_client_n()                     # resident shards hit the cache
    assert disk.shard_opens >= 5


# --------------------------------------------------------------------------
# warm tier + pinning
# --------------------------------------------------------------------------

def test_population_store_warm_lru_bound_and_counters():
    src = SyntheticClientSource(40, seed=0, shard_size=8, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=4)
    for cid in range(10):
        store.get(cid)
    assert len(store.warm) == 4 and store.peak_warm == 4
    assert store.cold_loads == 10 and store.warm_evictions == 6
    store.get(9)                             # most recent: a hit
    assert store.warm_hits == 1
    store.get(0)                             # evicted long ago: a reload
    assert store.cold_loads == 11


def test_population_store_pinned_survive_eviction_pressure():
    src = SyntheticClientSource(40, seed=0, shard_size=8, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=3)
    store.get(0)
    store.get(1)
    store.pin([0, 1])
    for cid in range(2, 12):
        store.get(cid)
    assert 0 in store.warm and 1 in store.warm     # never evicted
    assert len(store.warm) <= 3
    store.unpin([0, 1])
    for cid in range(12, 16):
        store.get(cid)
    assert 0 not in store.warm and 1 not in store.warm


def test_population_store_all_pinned_exceeds_cap_not_corrupts():
    src = SyntheticClientSource(10, seed=0, shard_size=4, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=2)
    store.pin(range(5))
    for cid in range(5):
        store.get(cid)
    assert len(store.warm) == 5              # bound traded for correctness
    assert store.peak_warm == 5 and store.warm_evictions == 0


def test_warm_eviction_drops_hot_slab():
    """Tier coherence: a client leaving the warm host tier must lose its
    device slab too (drop, not LRU eviction), and hot LRU evictions must
    feed back into population telemetry."""
    src = SyntheticClientSource(20, seed=0, shard_size=8, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=2)
    hot = ClientSlabStore(max_resident=8)
    store.attach_hot(hot)
    dev = jax.devices()[0]
    for cid in range(4):
        hot.get(cid, store.get(cid), dev)
    # warm cap 2 ⇒ clients 0/1 were warm-evicted ⇒ hot dropped them
    assert set(hot.slabs) == {2, 3}
    assert hot.drops == 2 and hot.evictions == 0
    assert store.warm_evictions == 2
    # hot pinned set is shared by reference with the population store
    store.pin([2])
    assert 2 in hot.pinned


def test_attach_hot_chains_prior_on_evict_and_merges_pins():
    """Satellite regression: attaching the population tier to a slab
    store that already carries an ``on_evict`` observer and pins must
    CHAIN the callback (both fire) and MERGE the pinned ids — the old
    behavior silently clobbered both."""
    src = SyntheticClientSource(20, seed=0, shard_size=8, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=16)
    seen = []
    hot = ClientSlabStore(max_resident=2,
                          on_evict=lambda cid, entry: seen.append(cid))
    hot.pinned.add(0)                        # pinned BEFORE attach
    store.attach_hot(hot)
    assert 0 in store.pinned and hot.pinned is store.pinned
    dev = jax.devices()[0]
    for cid in range(4):
        hot.get(cid, store.get(cid), dev)
    # cap 2, cid 0 pinned ⇒ 1 and 2 cap-evict; the prior observer saw
    # them AND the population telemetry counted them
    assert seen == [1, 2]
    assert store.hot_evictions == 2
    assert 0 in hot.slabs


def test_attach_hot_order_does_not_lose_pins():
    """Pin survival is symmetric in attach order: population-side pins
    made before attach reach the slab store through the shared set."""
    src = SyntheticClientSource(10, seed=0, shard_size=4, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=8)
    store.pin([3])
    hot = ClientSlabStore(max_resident=1)
    store.attach_hot(hot)
    assert 3 in hot.pinned
    dev = jax.devices()[0]
    hot.get(3, store.get(3), dev)
    for cid in (4, 5):
        hot.get(cid, store.get(cid), dev)
    assert 3 in hot.slabs                    # never cap-evicted


def test_client_n_warm_hit_counts_and_refreshes_lru():
    """Satellite regression: a ``client_n`` size read against a warm
    client is a USE — it ticks ``warm_hits`` and refreshes recency so
    eviction order and telemetry agree with ``get()``."""
    src = SyntheticClientSource(10, seed=0, shard_size=4, min_n=3, max_n=6)
    store = PopulationStore(src, warm_cap=2)
    store.get(0)
    store.get(1)                             # LRU order: 0, 1
    assert store.warm_hits == 0
    assert store.client_n(0) == src.client_n(0)
    assert store.warm_hits == 1              # warm size read counted
    store.get(2)                             # cap 2 ⇒ evicts LRU: now 1
    assert 0 in store.warm and 1 not in store.warm
    # a cold size read touches the source only — no warm pollution
    n = store.client_n(7)
    assert n == src.client_n(7)
    assert 7 not in store.warm and store.warm_hits == 1


# --------------------------------------------------------------------------
# ClientSlabStore: drop / on_evict / pinning (satellite regression)
# --------------------------------------------------------------------------

def test_slab_store_drop_and_resample_counters():
    """Dropping a resident client then re-sampling it must read as ONE
    drop + a fresh host transfer — evictions and peak_resident untouched."""
    store = ClientSlabStore(max_resident=4)
    dev = jax.devices()[0]
    datas = {cid: ClientData(np.ones((5, 2), np.float32),
                             np.zeros(5, np.int64)) for cid in range(3)}
    for cid in range(3):
        store.get(cid, datas[cid], dev)
    assert store.host_transfers == 3 and store.peak_resident == 3
    assert store.drop(1)
    assert not store.drop(1)                 # idempotent: already gone
    assert store.stats()["resident_clients"] == 2
    assert store.evictions == 0 and store.drops == 1
    assert store.peak_resident == 3          # high-water is historical
    store.get(1, datas[1], dev)              # re-sample: fresh upload
    assert store.host_transfers == 4 and store.hits == 0
    store.get(1, datas[1], dev)
    assert store.hits == 1
    assert store.stats()["drops"] == 1


def test_slab_store_on_evict_fires_only_for_cap_evictions():
    seen = []
    store = ClientSlabStore(max_resident=2,
                            on_evict=lambda cid, entry: seen.append(cid))
    dev = jax.devices()[0]
    data = ClientData(np.ones((4, 2), np.float32), np.zeros(4, np.int64))
    for cid in range(4):
        store.get(cid, data, dev)
    assert seen == [0, 1] and store.evictions == 2
    store.drop(2)
    assert seen == [0, 1]                    # drop is caller-initiated


def test_slab_store_pinned_never_cap_evicted():
    store = ClientSlabStore(max_resident=2)
    dev = jax.devices()[0]
    data = ClientData(np.ones((4, 2), np.float32), np.zeros(4, np.int64))
    store.get(0, data, dev)
    store.pinned.add(0)
    for cid in range(1, 5):
        store.get(cid, data, dev)
    assert 0 in store.slabs
    assert store.stats()["resident_clients"] == 2


# --------------------------------------------------------------------------
# client-state tiers
# --------------------------------------------------------------------------

def test_state_store_stateless_holds_nothing():
    calls = []

    def init(cid):
        calls.append(cid)
        return ()

    states = ClientStateStore(init, mutable=False)
    assert states[3] == ()
    states[3] = ("ignored",)                 # write-back is a no-op
    assert states[3] == ()
    assert len(states.warm) == 0 and calls == [3, 3]


def test_state_store_stateful_spills_and_reloads(tmp_path):
    def init(cid):
        return {"prev": {"w": jnp.zeros((3,), jnp.float32)}}

    states = ClientStateStore(init, mutable=True, warm_cap=2,
                              spill_dir=str(tmp_path))
    for cid in range(4):
        states[cid] = {"prev": {"w": jnp.full((3,), float(cid))}}
    assert len(states.warm) == 2 and states.state_spills == 2
    assert states.spilled == {0, 1}
    assert os.path.exists(os.path.join(str(tmp_path), "state_000000000.npz"))
    got = states[0]                          # round-trips through disk
    assert float(got["prev"]["w"][0]) == 0.0
    got = states[1]
    assert float(got["prev"]["w"][0]) == 1.0
    assert states.state_loads == 2
    # never-seen client: plain init, no disk touch
    fresh = states[9]
    assert float(fresh["prev"]["w"][0]) == 0.0 and states.state_inits == 1


def test_state_store_pinned_states_not_evicted(tmp_path):
    pinned = {0}
    states = ClientStateStore(lambda cid: {"v": jnp.zeros(())}, mutable=True,
                              warm_cap=2, spill_dir=str(tmp_path),
                              pinned=pinned)
    for cid in range(5):
        states[cid] = {"v": jnp.full((), float(cid))}
    assert 0 in states.warm and len(states.warm) == 2


def test_state_store_eviction_storm_all_pinned_exceeds_cap(tmp_path):
    """Evict-while-pinned: when every warm entry is pinned the tier grows
    past ``warm_cap`` rather than spilling a pinned state — a mid-round
    cohort must never lose state it is actively training."""
    pinned = {0, 1, 2, 3}
    states = ClientStateStore(lambda cid: {"v": jnp.zeros(())}, mutable=True,
                              warm_cap=2, spill_dir=str(tmp_path),
                              pinned=pinned)
    for cid in range(4):
        states[cid] = {"v": jnp.full((), float(cid))}
    assert len(states.warm) == 4            # over cap, nothing spilled
    assert states.state_spills == 0 and states.spilled == set()
    # unpinning and touching a new client drains the backlog down to cap
    pinned.clear()
    states[7] = {"v": jnp.full((), 7.0)}
    assert len(states.warm) == 2
    assert states.state_spills == 3
    # spilled values survived the storm bit-exact
    for cid in (0, 1, 2):
        assert float(states[cid]["v"]) == float(cid)


def test_state_store_corrupt_spill_reinits_with_warning(tmp_path, caplog):
    """A torn/garbage spill file (crash mid-save, disk fault) must not kill
    the run: the client falls back to its initial state with a logged
    warning and the ``state_corrupt_reinits`` counter ticks."""
    def init(cid):
        return {"w": jnp.zeros((3,))}

    states = ClientStateStore(init, mutable=True, warm_cap=1,
                              spill_dir=str(tmp_path))
    states[0] = {"w": jnp.full((3,), 5.0)}
    states[1] = {"w": jnp.full((3,), 6.0)}      # evicts + spills client 0
    assert states.spilled == {0}
    path = os.path.join(str(tmp_path), "state_000000000.npz")
    with open(path, "r+b") as f:                # tear the spill mid-file
        f.truncate(32)
    with caplog.at_level("WARNING", logger="repro.population"):
        got = states[0]
    assert float(got["w"][0]) == 0.0            # re-initialized, not 5.0
    assert 0 not in states.spilled              # corrupt file forgotten
    assert states.state_corrupt_reinits == 1
    assert states.stats()["state_corrupt_reinits"] == 1
    assert any("corrupt state spill" in r.message for r in caplog.records)
    # the store heals: the re-evicted state round-trips cleanly afterwards
    states[2] = {"w": jnp.full((3,), 7.0)}      # evicts 0 again, clean spill
    assert float(states[0]["w"][0]) == 0.0


def test_state_store_snapshot_is_by_value(tmp_path):
    """Satellite regression: ``snapshot()`` must capture warm states by
    VALUE.  A client trained AFTER the checkpoint was cut mutates its
    (numpy-leafed) state in place; resume must see the checkpoint-time
    value, not the later one."""
    def init(cid):
        return {"prev": {"w": np.zeros((3,), np.float32)}, "step": 0}

    states = ClientStateStore(init, mutable=True, warm_cap=8,
                              spill_dir=str(tmp_path))
    live = {"prev": {"w": np.full((3,), 5.0, np.float32)}, "step": 4}
    states[0] = live
    snap = states.snapshot()
    # round t+1 trains client 0 further, mutating leaves AND containers
    live["prev"]["w"][:] = 99.0
    live["step"] = 5
    restored = ClientStateStore(init, mutable=True, warm_cap=8,
                                spill_dir=str(tmp_path))
    restored.restore(snap)
    got = restored[0]
    assert float(got["prev"]["w"][0]) == 5.0 and got["step"] == 4
    assert restored.state_hits == 1          # warm on arrival, no reload
# --------------------------------------------------------------------------
# run_federated(population=): equivalence + seed sequences
# --------------------------------------------------------------------------

def test_run_federated_requires_exactly_one_source(tiny_setup):
    task, data = tiny_setup
    algo = algorithms.make("fedavg")
    with pytest.raises(ValueError):
        fl_loop.run_federated(task, algo)
    with pytest.raises(ValueError):
        fl_loop.run_federated(task, algo, data,
                              population=Population.from_federated(data))


@pytest.mark.parametrize("name", ["fedavg", "fedgkd", "moon"])
@pytest.mark.parametrize("spec", ["sequential", "vmap"])
def test_population_matches_eager_data(tiny_setup, name, spec):
    """The acceptance criterion: population= at n_shards=1 leaves every
    executor's numbers unchanged (stateless AND moon's stateful path)."""
    task, data = tiny_setup
    h0 = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               executor=spec)
    h1 = fl_loop.run_federated(task, algorithms.make(name),
                               population=Population.from_federated(data),
                               seed=0, executor=spec)
    assert _max_param_diff(h0.final_params, h1.final_params) < 1e-5
    for r0, r1 in zip(h0.records, h1.records):
        assert r0.sampled == r1.sampled
        assert abs(r0.mean_local_loss - r1.mean_local_loss) < 1e-5
        assert abs(r0.test_acc - r1.test_acc) < 1e-5
    assert "population" in h1.telemetry
    assert "population" not in h0.telemetry


@pytest.mark.parametrize("name", ["fedavg", "fedgkd-vote"])
def test_population_matches_eager_data_async(tiny_setup, name):
    task, data = tiny_setup
    kw = dict(seed=0, rounds=4)
    h0 = fl_loop.run_federated(task, algorithms.make(name), data,
                               executor=ex.AsyncExecutor(
                                   staleness="constant", buffer_size=2), **kw)
    h1 = fl_loop.run_federated(task, algorithms.make(name),
                               population=Population.from_federated(data),
                               executor=ex.AsyncExecutor(
                                   staleness="constant", buffer_size=2), **kw)
    assert _max_param_diff(h0.final_params, h1.final_params) < 1e-5
    for r0, r1 in zip(h0.records, h1.records):
        assert r0.sampled == r1.sampled


@multidevice
def test_population_matches_eager_data_shard_map(tiny_setup):
    task, data = tiny_setup
    h0 = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor=ex.ShardMapExecutor(strict=True))
    h1 = fl_loop.run_federated(task, algorithms.make("fedgkd"),
                               population=Population.from_federated(data),
                               seed=0,
                               executor=ex.ShardMapExecutor(strict=True))
    assert _max_param_diff(h0.final_params, h1.final_params) < 1e-5
    assert h1.telemetry["route"] == "shard_map"
    assert h1.telemetry["population"]["cold_loads"] >= 1


def _cohort_task(rounds):
    return dataclasses.replace(TOY, n_clients=30, participation=0.2,
                               rounds=rounds, local_epochs=1, batch_size=16)


def test_seed_equivalence_sync_cohorts_50_rounds():
    """Satellite: hierarchical sampling at n_shards=1 reproduces the flat
    rng.choice cohort SEQUENCE bit-identically over 50 sync rounds."""
    task = _cohort_task(50)
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(int(n), seed=200 + i))
               for i, n in enumerate(
                   np.random.default_rng(5).integers(8, 30, 30))]
    tx, ty = gen.generate(64, seed=999)
    data = FederatedData(clients, tx, ty, np.zeros((30, task.num_classes)))
    kw = dict(seed=7, executor="sequential", max_batches_per_client=1,
              eval_every=1000, width=4)
    h0 = fl_loop.run_federated(task, algorithms.make("fedavg"), data, **kw)
    h1 = fl_loop.run_federated(task, algorithms.make("fedavg"),
                               population=Population.from_federated(data),
                               **kw)
    assert len(h0.records) == 50
    assert [r.sampled for r in h0.records] == [r.sampled for r in h1.records]


def test_seed_equivalence_async_wave_refills_50_rounds():
    """Satellite: same guarantee for the async loop's excluded-idle wave
    refills (in-flight clients change the draw geometry every wave)."""
    task = _cohort_task(50)
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(int(n), seed=300 + i))
               for i, n in enumerate(
                   np.random.default_rng(6).integers(8, 30, 30))]
    tx, ty = gen.generate(64, seed=999)
    data = FederatedData(clients, tx, ty, np.zeros((30, task.num_classes)))
    kw = dict(seed=7, max_batches_per_client=1, eval_every=1000, width=4,
              executor=ex.AsyncExecutor(staleness="constant", buffer_size=3,
                                        profile=SpeedProfile(
                                            kind="lognormal")))
    h0 = fl_loop.run_federated(task, algorithms.make("fedavg"), data, **kw)
    h1 = fl_loop.run_federated(task, algorithms.make("fedavg"),
                               population=Population.from_federated(data),
                               **kw)
    assert len(h0.records) == 50
    assert [r.sampled for r in h0.records] == [r.sampled for r in h1.records]
    # in-flight-at-termination clients must not stay pinned (a reused
    # Population would exempt them from eviction forever)
    assert h1.telemetry["population"]["pinned"] == 0


def test_multi_shard_population_trains_with_bounded_warm_tier(tiny_setup):
    """More shards than one: no bit-equivalence claim, but the run must
    train, respect the warm cap, and keep the cohort marginal sane."""
    task, data = tiny_setup
    population = Population.from_federated(data, n_shards=3, warm_cap=3)
    h = fl_loop.run_federated(task, algorithms.make("moon"),
                              population=population, seed=0, rounds=4,
                              executor="vmap")
    stats = h.telemetry["population"]
    assert stats["n_shards"] == 3
    assert stats["peak_warm"] <= 3
    assert stats["warm_evictions"] > 0
    assert len(h.records) == 4
    cohorts = {c for r in h.records for c in r.sampled}
    assert cohorts <= set(range(task.n_clients))


def test_population_pins_cohort_during_round(tiny_setup):
    """warm_cap == cohort size: the round's own clients must not evict one
    another mid-materialization (pinning), and must all unpin after."""
    task, data = tiny_setup            # participation 0.5 of 6 ⇒ cohort 3
    population = Population.from_federated(data, warm_cap=3)
    h = fl_loop.run_federated(task, algorithms.make("fedavg"),
                              population=population, seed=0, rounds=3,
                              executor="sequential")
    stats = h.telemetry["population"]
    assert stats["pinned"] == 0                       # all released
    assert stats["peak_warm"] <= 3 + 1                # cap + probe client
    # every round's cohort was materialized exactly once or hit warm
    assert stats["cold_loads"] + stats["warm_hits"] >= 3 * 3


def test_population_from_disk_shards(tmp_path, tiny_setup):
    """End-to-end out-of-core: write the eager dataset to disk shards,
    train from the DiskShardSource, match the eager run bit-for-bit."""
    task, data = tiny_setup
    write_population_shards(str(tmp_path),
                            (c for c in data.clients), shard_size=4)
    src = DiskShardSource(str(tmp_path))
    # one logical shard for the sampler: geometry stays bit-compatible
    sampler_compat = Population(
        InMemorySource(data.clients, n_shards=1), data.test_x, data.test_y)
    population = Population(src, data.test_x, data.test_y, warm_cap=4)
    population.sampler = sampler_compat.sampler
    h0 = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               executor="sequential")
    h1 = fl_loop.run_federated(task, algorithms.make("fedavg"),
                               population=population, seed=0,
                               executor="sequential")
    assert _max_param_diff(h0.final_params, h1.final_params) < 1e-5


# --------------------------------------------------------------------------
# the million-client bound (--runslow)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_million_client_run_is_warm_cap_bounded():
    """1M registered clients, K=64 cohorts: the run completes with the
    store never holding more than the warm cap (+ pinned cohort), and the
    work done scales with SAMPLED clients, not the population."""
    population = Population.synthetic(1_000_000, warm_cap=128,
                                     shard_size=4096, min_n=8, max_n=24,
                                     seed=0, n_test=128)
    task = dataclasses.replace(TOY, n_clients=1_000_000,
                               participation=64 / 1_000_000, rounds=2,
                               local_epochs=1, batch_size=16)
    h = fl_loop.run_federated(task, algorithms.make("fedavg"),
                              population=population, seed=0,
                              executor="vmap", max_batches_per_client=1,
                              eval_every=1000, width=4)
    stats = h.telemetry["population"]
    assert population.n_shards == 245
    assert all(len(r.sampled) == 64 for r in h.records)
    assert stats["peak_warm"] <= 128
    # cold loads = sampled cohorts + the probe client, NOT O(population)
    assert stats["cold_loads"] <= 2 * 64 + 1
    assert stats["state_peak_warm"] == 0          # fedavg: stateless tier
    assert len(population.store.warm) <= 128
