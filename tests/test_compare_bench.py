"""The bench regression gate itself (benchmarks/compare_bench.py).

Every nightly bench job funnels through this one comparator, so a bug
here — an inverted direction, a silently-empty case overlap, a case key
that collapses distinct rows — would turn every nightly gate green while
the tree regresses.  Covered: higher- and lower-is-better directions on
both sides of the tolerance edge, the per-host ``host`` key field, the
missing-case / no-overlap paths, and the top-level environment refusal.
"""

import copy
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "benchmarks"))

import compare_bench  # noqa: E402  (path insert above)


def _payload(cases, **top):
    base = {"task": "toy", "devices": 8, "backend": "cpu", "clients": 64,
            "width": 8}
    base.update(top)
    base["cases"] = cases
    return base


def _case(**kw):
    row = {"algo": "fedavg", "executor": "vmap", "epochs": 1,
           "precompute": False}
    row.update(kw)
    return row


def _run(tmp_path, baseline, fresh, tolerance=0.20):
    b = tmp_path / "base.json"
    n = tmp_path / "new.json"
    b.write_text(json.dumps(baseline))
    n.write_text(json.dumps(fresh))
    return compare_bench.main([str(b), str(n), "--tolerance",
                               str(tolerance)])


# ---------------------------------------------------------------------------
# directions and tolerance edges
# ---------------------------------------------------------------------------

def test_higher_better_pass_and_regression(tmp_path):
    base = _payload([_case(speedup_vs_sequential=10.0)])
    ok = _payload([_case(speedup_vs_sequential=8.5)])     # -15% < 20% tol
    (tmp_path / "a").mkdir()
    assert _run(tmp_path / "a", base, ok) == 0
    bad = _payload([_case(speedup_vs_sequential=7.9)])    # -21% > 20% tol
    (tmp_path / "b").mkdir()
    assert _run(tmp_path / "b", base, bad) == 1


def test_lower_better_pass_and_regression(tmp_path):
    base = _payload([_case(peak_host_rss_mb=500.0)])
    ok = _payload([_case(peak_host_rss_mb=590.0)])        # +18% < 20% tol
    (tmp_path / "a").mkdir()
    assert _run(tmp_path / "a", base, ok) == 0
    bad = _payload([_case(peak_host_rss_mb=610.0)])       # +22% > 20% tol
    (tmp_path / "b").mkdir()
    assert _run(tmp_path / "b", base, bad) == 1


def test_lower_better_improvement_never_fails(tmp_path):
    base = _payload([_case(host_crash_recovery_rounds=4)])
    better = _payload([_case(host_crash_recovery_rounds=1)])
    assert _run(tmp_path, base, better) == 0


def test_exact_tolerance_boundary_is_ok(tmp_path):
    # new == base * (1 - tol) passes for higher-better (>=, not >), and
    # new == base * (1 + tol) passes for lower-better (<=)
    base = _payload([_case(async_client_updates_per_sec=10.0,
                           peak_warm=100)])
    edge = _payload([_case(async_client_updates_per_sec=8.0,
                           peak_warm=120)])
    assert _run(tmp_path, base, edge, tolerance=0.20) == 0


def test_new_chaos_metrics_are_gated():
    # the nightly multihost-chaos job depends on these exact names and
    # directions — losing either silently un-gates the chaos bench
    assert "async_client_updates_per_sec" in compare_bench.METRICS
    assert "host_crash_recovery_rounds" in compare_bench.METRICS_LOWER


# ---------------------------------------------------------------------------
# case keying
# ---------------------------------------------------------------------------

def test_host_field_distinguishes_per_host_cases(tmp_path):
    # one regressed host must fail even when its peer improved
    base = _payload([_case(host="host0", peak_warm=100),
                     _case(host="host1", peak_warm=100)])
    fresh = _payload([_case(host="host0", peak_warm=50),
                      _case(host="host1", peak_warm=150)])
    assert _run(tmp_path, base, fresh) == 1
    rows = compare_bench.compare(base, fresh, 0.20)
    verdicts = {r["key"][-1]: r["ok"] for r in rows}
    assert verdicts == {"host0": True, "host1": False}


def test_case_key_tolerates_artifacts_predating_new_fields():
    old = _case(speedup_vs_sequential=2.0)          # no faults/host fields
    new = _case(speedup_vs_sequential=2.0, faults=None, host=None)
    assert compare_bench.case_key(old) == compare_bench.case_key(new)


def test_faults_field_distinguishes_chaos_cases():
    clean = _case(executor="async", peak_host_rss_mb=300.0)
    chaotic = _case(executor="async", peak_host_rss_mb=300.0,
                    faults="crash0.05+corrupt0.05+host0.2")
    assert compare_bench.case_key(clean) != compare_bench.case_key(chaotic)


# ---------------------------------------------------------------------------
# overlap and environment handling
# ---------------------------------------------------------------------------

def test_disjoint_cases_is_not_a_failure(tmp_path, capsys):
    # baseline may predate new cases (and a chaos-only fresh payload may
    # overlap none of the memory cases): exit 0, but say so
    base = _payload([_case(executor="shard_map", peak_warm=100)])
    fresh = _payload([_case(executor="async", peak_warm=999)])
    assert _run(tmp_path, base, fresh) == 0
    assert "no overlapping cases" in capsys.readouterr().out


def test_shared_key_missing_metric_is_skipped(tmp_path):
    # same case key, disjoint metric sets: nothing comparable -> exit 0
    base = _payload([_case(speedup_vs_sequential=2.0)])
    fresh = _payload([_case(peak_warm=10)])
    assert _run(tmp_path, base, fresh) == 0


def test_mismatched_environment_refuses_with_exit_2(tmp_path):
    base = _payload([_case(peak_warm=100)])
    for field, val in (("devices", 1), ("backend", "gpu"),
                       ("clients", 32), ("width", 4)):
        fresh = copy.deepcopy(_payload([_case(peak_warm=100)]))
        fresh[field] = val
        d = tmp_path / field
        d.mkdir()
        assert _run(d, base, fresh) == 2


def test_missing_environment_field_in_baseline_is_tolerated(tmp_path):
    # artifacts predating a top-level field must not start refusing
    base = _payload([_case(peak_warm=100)])
    del base["width"]
    fresh = _payload([_case(peak_warm=100)])
    assert _run(tmp_path, base, fresh) == 0


def test_regression_report_names_metric(tmp_path, capsys):
    base = _payload([_case(async_client_updates_per_sec=10.0)])
    bad = _payload([_case(async_client_updates_per_sec=1.0)])
    assert _run(tmp_path, base, bad) == 1
    out = capsys.readouterr().out
    assert "async_client_updates_per_sec" in out
    assert "REGRESSED" in out


def test_committed_multihost_baseline_parses():
    # the committed artifact the nightly jobs gate against must keep
    # indexing cleanly (unique case keys, required key fields present)
    path = pathlib.Path(__file__).resolve().parent.parent
    with open(path / "BENCH_multihost.json") as f:
        payload = json.load(f)
    idx = compare_bench.index_cases(payload)
    assert len(idx) == len(payload["cases"])
    with pytest.raises(KeyError):
        compare_bench.case_key({})
