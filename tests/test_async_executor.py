"""Async straggler-aware rounds: systemsim virtual clock, staleness-aware
buffered aggregation, determinism, and cache behaviour under churning
async cohorts.

The synchronous-regime EQUIVALENCE suite (async vs sequential/vmap/
shard_map, including the K=6-on-8-devices multidevice gate) lives with
its siblings in ``tests/test_executor.py``; this file covers everything
the async structure adds on top:

  * property tests (``proptest.sweep``): staleness weights are
    non-negative, normalize to 1, polynomial decay is monotone
    non-increasing; the virtual clock never goes backwards; completion
    ordering is invariant to the consumer's buffer size when clients are
    equally fast;
  * bit-identical determinism of two same-seed async runs (histories AND
    telemetry — speeds and the event queue come from the seeded PRNG
    plumbing, never ``random``/wall time);
  * cache-under-churn: ``ClientSlabStore`` counters/LRU under async-style
    cohort churn, and the FedGKD-VOTE ``(client, version)`` part cache
    when stale arrivals bump ``ModelBuffer`` versions mid-buffer;
  * the ``--runslow`` straggler-profile sweep the nightly job runs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from proptest import rand_data_weights, rand_staleness, sweep
from repro.configs.paper import TOY
from repro.core import algorithms, executor as ex, fl_loop
from repro.core.server import (STALENESS_SCHEMES, async_aggregation_weights,
                               staleness_scale)
from repro.core.systemsim import (Availability, SpeedProfile, SystemSim,
                                  derive_rng, draw_speeds)
from repro.data.pipeline import ClientData, ClientSlabStore, FederatedData
from repro.data.synthetic import SyntheticTabularTask

RAGGED_SIZES = (20, 45, 64, 100, 130, 150)

STRAGGLER = SpeedProfile(kind="straggler", straggler_frac=0.25,
                         straggler_slowdown=4.0)


def _ragged_data(task, sizes=RAGGED_SIZES):
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(sizes)]
    test_x, test_y = gen.generate(200, seed=999)
    return FederatedData(clients, test_x, test_y,
                         np.zeros((len(sizes), task.num_classes)))


@pytest.fixture(scope="module")
def tiny_setup():
    task = dataclasses.replace(TOY, n_clients=len(RAGGED_SIZES),
                               participation=1.0, batch_size=64, rounds=2,
                               local_epochs=2)
    return task, _ragged_data(task)


# --- staleness weighting properties -----------------------------------------

@sweep(20)
def test_prop_weights_nonneg_and_normalized(rng):
    n = int(rng.integers(1, 12))
    ws = rand_data_weights(rng, n)
    st = rand_staleness(rng, n)
    scheme = STALENESS_SCHEMES[int(rng.integers(len(STALENESS_SCHEMES)))]
    a = float(rng.uniform(0.0, 3.0))
    cutoff = float(rng.integers(0, 6)) if rng.random() < 0.5 else None
    out = async_aggregation_weights(ws, st, scheme, a=a, cutoff=cutoff)
    assert all(w >= 0.0 for w in out), (scheme, out)
    assert abs(sum(out) - 1.0) < 1e-9, (scheme, sum(out))
    raw = async_aggregation_weights(ws, st, scheme, a=a, cutoff=cutoff,
                                    normalize=False)
    assert all(w >= 0.0 for w in raw)
    if scheme == "constant":            # raw products ARE the sync weights
        np.testing.assert_allclose(raw, ws)


@sweep(20)
def test_prop_polynomial_monotone_in_staleness(rng):
    a = float(rng.uniform(0.0, 3.0))
    st = np.sort(rand_staleness(rng, 16))
    scales = [staleness_scale(s, "polynomial", a=a) for s in st]
    assert all(x >= 0.0 for x in scales)
    assert all(x >= y - 1e-12 for x, y in zip(scales, scales[1:])), \
        "polynomial staleness scale must be monotone non-increasing"
    assert staleness_scale(0.0, "polynomial", a=a) == 1.0


def test_fedgkd_scheme_cutoff_and_fallback():
    # beyond the cutoff an update is dropped from averaging ...
    assert staleness_scale(3, "fedgkd", cutoff=2) == 0.0
    assert staleness_scale(2, "fedgkd", cutoff=2) > 0.0
    # ... and an ALL-stale buffer falls back to plain data weights
    out = async_aggregation_weights([10.0, 30.0], [5, 9], "fedgkd", cutoff=2)
    np.testing.assert_allclose(out, [0.25, 0.75])
    with pytest.raises(ValueError):
        staleness_scale(1, "nope")


# --- virtual-clock properties -----------------------------------------------

@sweep(15)
def test_prop_clock_never_goes_backwards(rng):
    kind = ("straggler", "lognormal", "uniform")[int(rng.integers(3))]
    n = int(rng.integers(2, 10))
    av = (Availability(period=float(rng.uniform(4, 32)),
                       duty=float(rng.uniform(0.3, 1.0)))
          if rng.random() < 0.5 else None)
    sim = SystemSim(n, SpeedProfile(kind=kind), availability=av, rng=rng)
    for c in range(n):
        sim.dispatch(c, work=float(rng.uniform(0.5, 8.0)))
    last_now, last_t = sim.now, 0.0
    for _ in range(40):
        comp = sim.pop()
        assert sim.now >= last_now, "virtual clock went backwards"
        assert comp.time >= last_t, "completions popped out of time order"
        last_now, last_t = sim.now, comp.time
        sim.dispatch(comp.client, work=float(rng.uniform(0.5, 8.0)))
    assert sim.dispatches == n + 40


@sweep(10)
def test_prop_event_order_invariant_to_buffer_size(rng):
    """Equally fast clients with equal work complete in dispatch order —
    whatever buffer size the aggregation loop drains with."""
    n = int(rng.integers(3, 9))
    total = 6 * n
    seed = int(rng.integers(2 ** 31))

    def drain_order(b):
        sim = SystemSim(n, SpeedProfile(kind="homogeneous"),
                        rng=np.random.default_rng(seed))
        for c in range(n):
            sim.dispatch(c, work=3.0)
        order = []
        while len(order) < total:
            batch = sim.pop_batch(min(b, sim.in_flight))
            order.extend(c.client for c in batch)
            for c in batch:
                sim.dispatch(c.client, work=3.0)
        return order[:total]

    ref = drain_order(1)
    for b in (2, 3, n):
        assert drain_order(b) == ref, f"buffer size {b} changed event order"


def test_draw_speeds_profiles():
    rng = np.random.default_rng(0)
    assert (draw_speeds(SpeedProfile(), 8, rng) == 1.0).all()
    s = draw_speeds(SpeedProfile(kind="straggler", straggler_frac=0.5,
                                 straggler_slowdown=4.0), 400, rng)
    assert set(np.unique(s)) == {0.25, 1.0}
    assert 0.3 < (s == 0.25).mean() < 0.7
    s = draw_speeds(SpeedProfile(kind="uniform", lo=0.5, hi=2.0), 100, rng)
    assert (s >= 0.5).all() and (s <= 2.0).all()
    assert (draw_speeds(SpeedProfile(kind="lognormal"), 100, rng) > 0).all()
    with pytest.raises(ValueError):
        SpeedProfile(kind="warp")


def test_availability_windows():
    av = Availability(period=10.0, duty=0.5)
    sim = SystemSim(2, SpeedProfile(), availability=av,
                    rng=np.random.default_rng(3))
    sim.phases = np.array([0.0, 5.0])   # pin phases: windows [0,5), [5,10)
    assert sim.next_available(0, 2.0) == 2.0        # inside the window
    assert sim.next_available(0, 7.0) == 10.0       # wait for next period
    assert sim.next_available(1, 2.0) == 5.0
    sim.now = 7.0
    sim.dispatch(0, work=1.0)
    assert sim.availability_delays == 1 and sim.total_wait == 3.0
    with pytest.raises(ValueError):
        Availability(duty=0.0)
    with pytest.raises(ValueError):
        Availability(period=-1.0)


def test_availability_boundaries_are_half_open():
    """Windows are ``[open, close)``: a dispatch landing EXACTLY on the
    closing edge has missed the window; one landing exactly on the opening
    edge starts immediately."""
    av = Availability(period=10.0, duty=0.5)
    sim = SystemSim(2, SpeedProfile(), availability=av,
                    rng=np.random.default_rng(3))
    sim.phases = np.array([0.0, 5.0])   # windows [0,5), [5,10) mod 10
    # exactly on the closing edge: closed, wait a full off-cycle
    assert sim.next_available(0, 5.0) == 10.0
    # exactly on the opening edge: open
    assert sim.next_available(0, 10.0) == 10.0
    assert sim.next_available(1, 5.0) == 5.0
    # gating applies to the START only — a run may COMPLETE outside the
    # window (mirrors real FL: a device uploads when training ends, the
    # duty cycle gates reachability for dispatch)
    sim.now = 4.0
    done = sim.dispatch(0, work=3.0)
    assert done == 7.0 and sim.availability_delays == 0


def test_dispatch_while_unavailable_delays_to_window():
    """A dispatch (or a backoff retry) issued while the client is dark
    starts at the next window opening, and the wait is metered."""
    av = Availability(period=10.0, duty=0.5)
    sim = SystemSim(2, SpeedProfile(), availability=av,
                    rng=np.random.default_rng(3))
    sim.phases = np.array([0.0, 5.0])
    sim.now = 6.0                       # client 0 is dark during [5, 10)
    done = sim.dispatch(0, work=1.0)
    assert done == 11.0
    assert sim.availability_delays == 1 and sim.total_wait == 4.0
    # a retry delay that lands inside the dark stretch slides to the same
    # window opening — backoff and availability compose, not race
    sim2 = SystemSim(2, SpeedProfile(), availability=av,
                     rng=np.random.default_rng(3))
    sim2.phases = np.array([0.0, 5.0])
    sim2.now = 2.0
    done = sim2.dispatch(0, work=1.0, delay=4.0)    # earliest start 6.0
    assert done == 11.0 and sim2.total_wait == 4.0
    # delay alone is NOT an availability wait: inside the window it adds
    # no metered delay
    sim3 = SystemSim(2, SpeedProfile(), availability=av,
                     rng=np.random.default_rng(3))
    sim3.phases = np.array([0.0, 5.0])
    done = sim3.dispatch(0, work=1.0, delay=2.0)     # starts at 2, runs 1
    assert done == 3.0
    assert sim3.availability_delays == 0 and sim3.total_wait == 0.0


def test_pop_empty_and_overdrain_raise():
    sim = SystemSim(2, SpeedProfile(), rng=np.random.default_rng(0))
    with pytest.raises(RuntimeError):
        sim.pop()
    sim.dispatch(0, work=1.0)
    with pytest.raises(RuntimeError):
        sim.pop_batch(2)


def test_derive_rng_is_stable_and_independent():
    a, b = derive_rng(7), derive_rng(7)
    np.testing.assert_array_equal(a.random(8), b.random(8))
    # a child stream, not the training stream itself
    assert not np.allclose(derive_rng(7).random(8),
                           np.random.default_rng(7).random(8))


# --- determinism -------------------------------------------------------------

def _async_exec():
    return ex.AsyncExecutor(buffer_size=3, staleness="fedgkd",
                            staleness_a=0.5, staleness_cutoff=4,
                            profile=STRAGGLER,
                            availability=Availability(period=24.0, duty=0.8))


def test_async_runs_are_bit_identical(tiny_setup):
    """Same seed => bit-identical histories and telemetry: every source of
    randomness (speeds, availability phases, event queue, sampling, batch
    draws) threads through the seeded PRNG plumbing."""
    task, data = tiny_setup
    runs = [fl_loop.run_federated(task, algorithms.make("fedgkd-vote",
                                                        buffer_m=3),
                                  data, seed=11, rounds=5,
                                  executor=_async_exec())
            for _ in range(2)]
    ra, rb = runs[0].records, runs[1].records
    assert len(ra) == len(rb) == 5
    for a, b in zip(ra, rb):
        for field in ("round", "test_acc", "test_loss", "mean_local_loss",
                      "sim_time", "version", "mean_staleness", "sampled"):
            assert getattr(a, field) == getattr(b, field), field
    assert runs[0].telemetry == runs[1].telemetry
    assert runs[0].local_model_acc == runs[1].local_model_acc
    # and a different seed actually changes the trajectory
    other = fl_loop.run_federated(task, algorithms.make("fedgkd-vote",
                                                        buffer_m=3),
                                  data, seed=12, rounds=5,
                                  executor=_async_exec())
    assert any(a.sampled != o.sampled or a.test_acc != o.test_acc
               for a, o in zip(ra, other.records))


def test_async_telemetry_and_records(tiny_setup):
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=3,
                              rounds=4,
                              executor=ex.AsyncExecutor(buffer_size=2,
                                                        profile=STRAGGLER))
    t = h.telemetry
    assert t["route"] == "async" and t["inner_route"] == "vmap"
    assert t["buffer_size"] == 2
    assert t["staleness_scheme"] == "polynomial"
    assert t["aggregations"] == 4 and t["final_version"] == 4
    assert t["sim"]["dispatches"] == 6 + 3 * 2   # initial fleet + 3 refills
    assert t["sim"]["in_flight"] == 6 - 2        # final refill is skipped
    sim_times = [r.sim_time for r in h.records]
    assert sim_times == sorted(sim_times) and sim_times[0] > 0.0
    assert [r.version for r in h.records] == [1, 2, 3, 4]
    assert all(len(r.sampled) == 2 for r in h.records)
    assert all(r.mean_staleness >= 0.0 for r in h.records)


def test_async_buffer_size_validation(tiny_setup):
    task, data = tiny_setup
    for bad in (0, 7):      # cohort is 6: a bigger buffer can never fill
        with pytest.raises(ValueError, match="buffer_size"):
            fl_loop.run_federated(task, algorithms.make("fedavg"), data,
                                  seed=0, rounds=1,
                                  executor=ex.AsyncExecutor(buffer_size=bad))


def test_sync_records_carry_sampled_cohort(tiny_setup):
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              rounds=2, executor="vmap")
    for r in h.records:
        assert len(r.sampled) == len(data.clients)
        assert set(r.sampled) <= set(range(data.n_clients))
        assert r.sim_time == 0.0 and r.version == 0     # sync defaults


# --- stale absorption into the KD teacher buffer ----------------------------

def test_absorb_stale_fuses_one_buffer_entry(tiny_setup):
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    algo = algorithms.make("fedgkd", buffer_m=3)
    model = make_model(task)
    gp = model.init(jax.random.PRNGKey(0))
    server = algo.init_server(gp, model, task.num_classes)
    v0 = list(server["buffer"].versions)
    mk = lambda f: jax.tree_util.tree_map(lambda p: p * f, gp)
    uploads = [{"params": mk(2.0)}, {"params": mk(4.0)}, {"params": mk(1.0)}]
    # no stale arrivals => no push
    server = algo.absorb_stale(server, uploads, [0, 0, 0], [1.0, 1.0, 1.0])
    assert list(server["buffer"].versions) == v0
    # two stale arrivals fuse (by data weight) into ONE new entry
    server = algo.absorb_stale(server, uploads, [2, 1, 0], [1.0, 3.0, 9.0])
    assert len(server["buffer"].versions) == len(v0) + 1
    fused = server["buffer"].models[0]
    want = jax.tree_util.tree_map(
        lambda a, b: 0.25 * a + 0.75 * b, mk(2.0), mk(4.0))
    diff = max(float(np.max(np.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(fused), jax.tree_util.tree_leaves(want)))
    assert diff < 1e-6
    # fedavg has no buffer: the base hook is a no-op
    avg = algorithms.make("fedavg")
    s2 = avg.init_server(gp, model, task.num_classes)
    assert avg.absorb_stale(s2, uploads, [3, 0, 0], [1.0, 1.0, 1.0]) is s2


def test_vote_absorb_keeps_val_losses_aligned(tiny_setup):
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    algo = algorithms.make("fedgkd-vote", buffer_m=3)
    model = make_model(task)
    gp = model.init(jax.random.PRNGKey(0))
    server = algo.init_server(gp, model, task.num_classes)
    # a second distinct entry (push de-duplicates bitwise-equal heads)
    server["buffer"].push(jax.tree_util.tree_map(lambda p: p * 1.01, gp))
    server["val_losses"] = [0.5, 0.7]
    uploads = [{"params": jax.tree_util.tree_map(lambda p: p * 2.0, gp)}]
    server = algo.absorb_stale(server, uploads, [2], [1.0])
    assert len(server["val_losses"]) == len(server["buffer"])
    # without a val batch the absorbed teacher is priced pessimistically
    assert server["val_losses"][0] == 0.7
    # with model+val_batch the losses are recomputed for every entry
    vx = np.asarray(data.test_x[:16])
    vy = np.asarray(data.test_y[:16])
    server = algo.absorb_stale(server, uploads, [1], [1.0], model=model,
                               val_batch=(vx, vy))
    assert len(server["val_losses"]) == len(server["buffer"]) == 3
    # FULL buffer: a push evicts the oldest entry and keeps len constant —
    # the refresh must still fire (regression: len-based push detection).
    # A DISTINCT upload: re-absorbing the same one would fuse to a bitwise
    # duplicate of the head, which push now rejects without a version bump
    uploads2 = [{"params": jax.tree_util.tree_map(lambda p: p * 3.0, gp)}]
    before = list(server["val_losses"])
    v_newest = server["buffer"].versions[0]
    server = algo.absorb_stale(server, uploads2, [3], [1.0], model=model,
                               val_batch=(vx, vy))
    assert server["buffer"].versions[0] == v_newest + 1
    assert len(server["val_losses"]) == len(server["buffer"]) == 3
    assert server["val_losses"] != before, \
        "full-buffer absorb must refresh the vote losses"
    # and without stale arrivals nothing is pushed or refreshed
    same = algo.absorb_stale(server, uploads, [0], [1.0])
    assert same["buffer"].versions[0] == v_newest + 1
    # and the payload built from the absorbed buffer stays well-formed
    payload = algo.round_payload(server, jax.random.PRNGKey(1))
    assert payload["gammas"].shape == (3,)
    # γ_m sum to 2λ (vote_coefficients: γ_m/2 = λ·softmax), every slot live
    assert abs(float(payload["gammas"].sum()) - 2 * algo.lam) < 1e-5
    assert (np.asarray(payload["gammas"]) > 0).all()


# --- cache behaviour under churning async cohorts ---------------------------

def test_slab_store_counters_under_async_churn():
    """Drive the slab store with the cohort churn an async run produces
    (fast clients return often, stragglers rarely): the LRU cap bounds
    residency the whole time and every access is exactly one of
    hit / host transfer / device move."""
    dev = jax.devices()[0]
    store = ClientSlabStore(max_resident=4)
    n = 6
    datas = [ClientData(np.zeros((8 + i, 2), np.float32),
                        np.zeros(8 + i, np.int64)) for i in range(n)]
    sim = SystemSim(n, STRAGGLER, rng=derive_rng(0))
    # pin a skewed fleet: 0/1 complete 8x as often as the 2..5 tail, so
    # their slabs re-hit while the tail's arrivals churn past the cap
    sim.speeds = np.array([4.0, 4.0, 0.5, 0.5, 0.5, 0.5])
    for c in range(n):
        sim.dispatch(c, work=4.0)
    gets = 0
    for _ in range(40):
        batch = sim.pop_batch(2)
        for comp in batch:
            store.get(comp.client, datas[comp.client], dev)
            gets += 1
            sim.dispatch(comp.client, work=4.0)
        assert len(store.slabs) <= 4, "LRU cap violated mid-churn"
    st = store.stats()
    assert st["peak_resident"] <= 4
    assert st["hits"] + st["host_transfers"] + st["device_moves"] == gets
    assert st["evictions"] > 0 and st["hits"] > 0
    assert st["evictions"] == st["host_transfers"] - min(
        4, st["host_transfers"])  # every transfer past the cap evicted one


def _vote_setup(task, m_teachers=3):
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    algo = algorithms.make("fedgkd-vote", buffer_m=m_teachers)
    model = make_model(task)
    gp = model.init(jax.random.PRNGKey(0))
    server = algo.init_server(gp, model, task.num_classes)
    for m in range(m_teachers - 1):
        server["buffer"].push(jax.tree_util.tree_map(
            lambda p: p * (1.0 + 0.01 * (m + 1)), gp))
    server["val_losses"] = [0.1 * (m + 1) for m in range(m_teachers)]
    ctx = ex.RoundContext(algo=algo, model=model, opt=sgd(), lr=0.05,
                          batch_size=64, epochs=1)
    return algo, model, gp, server, ctx


def test_vote_part_cache_absorb_bumps_recompute_exactly_once(tiny_setup):
    """An async stale-arrival absorption bumps the ModelBuffer version
    mid-buffer; the (client, version) part cache must recompute exactly
    the one absorbed teacher and stay bounded across churning cohorts."""
    task, data = tiny_setup
    m = 3
    algo, model, gp, server, ctx = _vote_setup(task, m)
    exec_ = ex.VmapExecutor()
    rng = np.random.default_rng(0)
    k = len(data.clients)
    payload0 = algo.round_payload(server, jax.random.PRNGKey(1))

    cohorts = [list(range(k)), list(range(k - 1, -1, -1)),
               [0, 2, 4], [1, 3, 5]]          # churn incl. partial cohorts
    for cohort in cohorts:
        exec_.run_round(ctx, gp, payload0, [() for _ in cohort],
                        [data.clients[c] for c in cohort], rng,
                        client_ids=cohort)
    assert ctx.telemetry["parts_computed"] == m, \
        "cohort churn without version bumps must never recompute"

    # async late arrival: the KD buffer absorbs a stale client model
    server = algo.absorb_stale(
        server, [{"params": jax.tree_util.tree_map(lambda p: p * 1.1, gp)}],
        [2], [1.0])
    payload1 = algo.round_payload(server, jax.random.PRNGKey(2))
    exec_.run_round(ctx, gp, payload1, [() for _ in range(k)],
                    data.clients, rng, client_ids=list(range(k)))
    assert ctx.telemetry["parts_computed"] == m + 1, \
        "absorb version bump must invalidate exactly one part"
    exec_.run_round(ctx, gp, payload1, [() for _ in range(k)],
                    data.clients, rng, client_ids=list(range(k)))
    assert ctx.telemetry["parts_computed"] == m + 1
    # rotated-out versions are evicted: the per-client cache stays at M
    for cid in range(k):
        assert len(ctx.aux_cache[cid]) <= m, "part cache grew unbounded"


def test_async_end_to_end_vote_absorbs_and_stays_bounded(tiny_setup):
    task, data = tiny_setup
    m = 3
    h = fl_loop.run_federated(
        task, algorithms.make("fedgkd-vote", buffer_m=m), data, seed=5,
        rounds=6,
        executor=ex.AsyncExecutor(buffer_size=2, staleness="fedgkd",
                                  profile=STRAGGLER))
    assert h.telemetry["stale_absorbed"] > 0, \
        "a straggler run must produce stale arrivals to absorb"
    assert np.isfinite([r.test_acc for r in h.records]).all()
    # versions created: M initial + 1 global push + 1 possible absorb per
    # aggregation — the part cache can never have computed more than that
    assert h.telemetry["parts_computed"] <= m + 2 * 6
    assert h.telemetry["max_staleness"] >= 1.0


# --- the launch-driver round clock ------------------------------------------

def test_launch_round_clock():
    from repro.launch.train import make_round_clock
    assert make_round_clock(4, straggler_frac=0.0, straggler_slowdown=4.0,
                            seed=0) is None
    clock = make_round_clock(64, straggler_frac=0.3, straggler_slowdown=4.0,
                             seed=0)
    # the barrier costs the slowest client: 4x the work at slowdown 4
    assert clock(8.0) == pytest.approx(32.0)
    clock2 = make_round_clock(64, straggler_frac=0.3, straggler_slowdown=4.0,
                              seed=0)
    assert clock(3.0) == clock2(3.0)        # seeded => reproducible


# --- nightly --runslow straggler-profile sweep ------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("profile", [
    SpeedProfile(kind="straggler", straggler_frac=0.2,
                 straggler_slowdown=4.0),
    SpeedProfile(kind="straggler", straggler_frac=0.4,
                 straggler_slowdown=8.0),
    SpeedProfile(kind="lognormal", sigma=0.8),
    SpeedProfile(kind="uniform", lo=0.25, hi=2.0),
], ids=["tail20x4", "tail40x8", "lognormal", "uniform"])
@pytest.mark.parametrize("scheme", ["constant", "polynomial", "fedgkd"])
def test_straggler_profile_sweep(tiny_setup, profile, scheme):
    """Every (profile, staleness scheme) combination trains to finite
    losses with a monotone virtual clock and full telemetry — the nightly
    wide-net over the async configuration space."""
    task, data = tiny_setup
    h = fl_loop.run_federated(
        task, algorithms.make("fedgkd", buffer_m=3), data, seed=2, rounds=5,
        executor=ex.AsyncExecutor(
            buffer_size=3, staleness=scheme, profile=profile,
            availability=Availability(period=32.0, duty=0.75)))
    assert len(h.records) == 5
    assert np.isfinite([r.test_acc for r in h.records]).all()
    assert np.isfinite([r.mean_local_loss for r in h.records]).all()
    times = [r.sim_time for r in h.records]
    assert times == sorted(times) and times[0] > 0.0
    assert h.telemetry["route"] == "async"
    assert h.telemetry["staleness_scheme"] == scheme
    assert h.telemetry["sim"]["speed_min"] > 0.0


# --- fixed-slot waves + pipelined dispatch ----------------------------------

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _slot_exec(wave_slots, pipelined, inner="vmap"):
    return ex.AsyncExecutor(buffer_size=3, staleness="fedgkd",
                            staleness_a=0.5, staleness_cutoff=4,
                            profile=STRAGGLER,
                            availability=Availability(period=24.0, duty=0.8),
                            inner=inner, wave_slots=wave_slots,
                            pipelined=pipelined)


def _history_key(h):
    return [(r.round, r.test_acc, r.test_loss, r.mean_local_loss,
             float(r.sim_time), r.version, r.mean_staleness, r.sampled)
            for r in h.records]


def test_wave_slots_validation():
    with pytest.raises(ValueError, match="wave_slots"):
        ex.AsyncExecutor(wave_slots="sometimes")
    with pytest.raises(ValueError, match="wave_slots"):
        ex.AsyncExecutor(wave_slots=0)


def test_fixed_slot_waves_bit_identical_to_variable(tiny_setup):
    """Padding every dispatch wave to B slots (phantom-client masks, S/B/
    rows pinned to population maxima) must not move a single bit of the
    aggregated history at zero faults — padded slots are exact identities
    through the scan's keep-masks."""
    task, data = tiny_setup
    mk = lambda: algorithms.make("fedgkd", buffer_m=3)  # noqa: E731
    h_fix = fl_loop.run_federated(task, mk(), data, seed=7, rounds=8,
                                  executor=_slot_exec("auto", False))
    h_var = fl_loop.run_federated(task, mk(), data, seed=7, rounds=8,
                                  executor=_slot_exec("variable", False))
    assert _history_key(h_fix) == _history_key(h_var)
    la = jax.tree_util.tree_leaves(h_fix.final_params)
    lb = jax.tree_util.tree_leaves(h_var.final_params)
    assert all(bool(np.all(np.asarray(x) == np.asarray(y)))
               for x, y in zip(la, lb))


def test_fixed_slot_compile_count_under_churn(tiny_setup):
    """Across a 30-round async run with churning wave geometry (ragged
    client sizes, initial 6-wave then 3-refills) the fixed-slot mode
    traces exactly ONE round body; the variable mode retraces per
    distinct (steps, batch, rows) signature."""
    task, data = tiny_setup
    mk = lambda: algorithms.make("fedavg")  # noqa: E731
    h_fix = fl_loop.run_federated(task, mk(), data, seed=0, rounds=30,
                                  eval_every=30,
                                  executor=_slot_exec("auto", True))
    h_var = fl_loop.run_federated(task, mk(), data, seed=0, rounds=30,
                                  eval_every=30,
                                  executor=_slot_exec("variable", False))
    assert h_fix.telemetry["compile_count"] == 1
    assert h_var.telemetry["compile_count"] >= 3


@pytest.mark.parametrize("algo", ["fedavg", "fedgkd", "fedgkd-vote"])
def test_pipelined_matches_single_stream(tiny_setup, algo):
    """Deferred host syncs + refill-before-eval change SCHEDULING only:
    the aggregated history agrees with the single-stream variable-wave
    path to < 1e-5 on every algorithm (in practice bit-identical on CPU;
    the tolerance allows accelerator reassociation)."""
    task, data = tiny_setup
    kw = {"buffer_m": 3} if algo.startswith("fedgkd") else {}
    mk = lambda: algorithms.make(algo, **kw)  # noqa: E731
    h_p = fl_loop.run_federated(task, mk(), data, seed=5, rounds=8,
                                executor=_slot_exec("auto", True))
    h_s = fl_loop.run_federated(task, mk(), data, seed=5, rounds=8,
                                executor=_slot_exec("variable", False))
    assert [r.sampled for r in h_p.records] == \
           [r.sampled for r in h_s.records]
    for a, b in zip(h_p.records, h_s.records):
        assert abs(a.test_acc - b.test_acc) < 1e-5
        assert abs(a.test_loss - b.test_loss) < 1e-5
        assert abs(a.mean_local_loss - b.mean_local_loss) < 1e-5


def test_sequential_inner_ignores_wave_slots(tiny_setup):
    """The sequential inner has no batched body to pin: wave_slots
    resolves to None (no padding, no compile_count telemetry) and the
    run still completes deferred-free."""
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              rounds=3,
                              executor=_slot_exec("auto", True,
                                                  inner="sequential"))
    assert len(h.records) == 3
    assert "compile_count" not in h.telemetry


def test_measure_step_time_positive_and_syncing():
    import jax.numpy as jnp

    from repro.core.systemsim import measure_step_time

    f = jax.jit(lambda x: (x @ x.T).sum())
    t = measure_step_time(f, jnp.ones((64, 64)), warmup=1, repeats=3)
    assert t > 0.0 and np.isfinite(t)


@multidevice
def test_fixed_slot_waves_shard_map_inner(tiny_setup):
    """Fixed-slot equivalence on the device mesh: the sharded inner pads
    wave slots to the device multiple on top of the B-slot padding and
    still reproduces the variable-wave history bit-for-bit, with ONE
    traced sharded round body."""
    task, data = tiny_setup
    mk = lambda: algorithms.make("fedgkd", buffer_m=3)  # noqa: E731
    h_fix = fl_loop.run_federated(task, mk(), data, seed=7, rounds=8,
                                  executor=_slot_exec("auto", True,
                                                      inner="shard_map"))
    h_var = fl_loop.run_federated(task, mk(), data, seed=7, rounds=8,
                                  executor=_slot_exec("variable", False,
                                                      inner="shard_map"))
    assert _history_key(h_fix) == _history_key(h_var)
    assert h_fix.telemetry["compile_count"] == 1
