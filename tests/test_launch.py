"""Launch-layer functional tests: serial FL LM driver + serving loop."""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch import serve as serve_lib
from repro.launch import train as train_lib
from repro.models import transformer


def test_serial_fl_lm_round_runs():
    cfg = get_smoke_config("phi4-mini-3.8b")
    out = train_lib.run_serial(cfg, rounds=1, n_clients=2,
                               batches_per_round=2, batch=2, seq=16,
                               algo="fedgkd", gamma=0.2, buffer_m=2,
                               lr=0.05, verbose=False)
    assert len(out["history"]) == 1
    assert np.isfinite(out["history"][0]["ppl"])


def test_serial_fedavg_vs_fedgkd_same_shapes():
    cfg = get_smoke_config("mamba2-2.7b")
    for algo in ("fedavg", "fedgkd"):
        out = train_lib.run_serial(cfg, rounds=1, n_clients=2,
                                   batches_per_round=1, batch=2, seq=16,
                                   algo=algo, verbose=False)
        assert np.isfinite(out["history"][0]["loss"])


def test_serve_loop_processes_queue():
    cfg = get_smoke_config("phi4-mini-3.8b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(5)]
    loop = serve_lib.ServeLoop(cfg, params, batch=2, max_len=32)
    stats = loop.run(prompts, gen=4)
    assert len(stats["outputs"]) == 5
    assert all(len(v) == 4 for v in stats["outputs"].values())
    assert stats["tok_per_s"] > 0


def test_client_batches_are_client_distinct():
    cfg = get_smoke_config("phi4-mini-3.8b")
    data = train_lib.client_batches(cfg, n_clients=3, batches_per_round=1,
                                    batch=4, seq=32, seed=0)
    assert data.shape == (3, 1, 4, 32)
    # different clients draw from different Markov sources
    assert not np.array_equal(data[0], data[1])
