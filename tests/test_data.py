"""Data pipeline: Dirichlet partitioning invariants + synthetic generators."""
import numpy as np

from repro.data.dirichlet import dirichlet_partition, partition_stats
from repro.data.pipeline import ClientData, batch_iterator, num_batches
from repro.data.synthetic import SyntheticImageTask, SyntheticTextTask
from proptest import sweep


@sweep(n=10)
def test_partition_is_disjoint_cover(rng):
    n = int(rng.integers(100, 800))
    c = int(rng.integers(2, 11))
    k = int(rng.integers(2, 9))
    labels = rng.integers(0, c, size=n)
    parts = dirichlet_partition(labels, k, alpha=float(rng.uniform(0.05, 2)),
                                seed=int(rng.integers(1 << 30)))
    all_idx = np.concatenate(parts)
    assert len(all_idx) == n
    assert len(np.unique(all_idx)) == n
    assert all(len(p) >= 2 for p in parts)


def _label_entropy(mat):
    p = mat / np.maximum(mat.sum(1, keepdims=True), 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        h = -np.nansum(np.where(p > 0, p * np.log(p), 0.0), axis=1)
    return h.mean()


def test_alpha_controls_skew():
    """Smaller α ⇒ more skewed per-client label distributions (paper Fig.3)."""
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=5000)
    ent = {}
    for alpha in (0.1, 1.0, 100.0):
        parts = dirichlet_partition(labels, 20, alpha, seed=1)
        ent[alpha] = _label_entropy(partition_stats(labels, parts))
    assert ent[0.1] < ent[1.0] < ent[100.0]


def test_batch_iterator_covers_epochs():
    data = ClientData(np.arange(50)[:, None].astype(np.float32),
                      np.arange(50) % 3)
    rng = np.random.default_rng(0)
    batches = list(batch_iterator(rng, data, batch_size=16, epochs=2))
    assert len(batches) == num_batches(50, 16, 2)
    assert all(x.shape[0] == 16 for x, _ in batches)


def test_image_task_learnable_structure():
    """Same-class samples must correlate more than cross-class (on average)."""
    gen = SyntheticImageTask(num_classes=4, hw=16, noise=0.3, seed=0)
    x, y = gen.generate(400)
    x = x.reshape(len(x), -1)
    x = (x - x.mean(1, keepdims=True)) / (x.std(1, keepdims=True) + 1e-8)
    sims = x @ x.T / x.shape[1]
    same = sims[y[:, None] == y[None, :]].mean()
    diff = sims[y[:, None] != y[None, :]].mean()
    assert same > diff + 0.05


def test_text_task_keywords_present():
    gen = SyntheticTextTask(num_classes=3, vocab_size=500, seq_len=32, seed=0)
    toks, y = gen.generate(300)
    assert toks.shape == (300, 32)
    assert toks.max() < 500 and toks.min() >= 0
    # class-conditional token histograms must differ
    h0 = np.bincount(toks[y == 0].ravel(), minlength=500)
    h1 = np.bincount(toks[y == 1].ravel(), minlength=500)
    h0 = h0 / h0.sum()
    h1 = h1 / h1.sum()
    assert np.abs(h0 - h1).sum() > 0.05


def test_generators_deterministic():
    g1 = SyntheticImageTask(num_classes=3, hw=8, seed=7).generate(10)
    g2 = SyntheticImageTask(num_classes=3, hw=8, seed=7).generate(10)
    np.testing.assert_array_equal(g1[0], g2[0])
    np.testing.assert_array_equal(g1[1], g2[1])
