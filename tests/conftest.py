import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests use subprocesses.


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_collection_modifyitems(config, items):
    """Fast default: @pytest.mark.slow tests only run under --runslow, so
    the tier-1 suite stays well inside the CI timeout."""
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
