"""Per-kernel allclose: fused KD-KL loss vs pure-jnp oracle.

Sweeps shapes/dtypes (interpret mode on CPU) and checks the custom VJP.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kd_kl import ops, ref
from proptest import sweep


def _check(lt, ls, temp=1.0, br=32, bv=128, tol=2e-4):
    out = ops.kd_kl_loss(lt, ls, temperature=temp, block_rows=br, block_vocab=bv)
    want = ref.kd_kl_rowwise(lt, ls, temp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("t,v", [(8, 128), (32, 128), (100, 300), (17, 1000),
                                 (256, 1024), (1, 64)])
def test_fwd_shapes(t, v):
    k1, k2 = jax.random.split(jax.random.PRNGKey(t * 1000 + v))
    _check(jax.random.normal(k1, (t, v)) * 3, jax.random.normal(k2, (t, v)) * 3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    lt = (jax.random.normal(k1, (64, 256)) * 3).astype(dtype)
    ls = (jax.random.normal(k2, (64, 256)) * 3).astype(dtype)
    out = ops.kd_kl_loss(lt, ls, block_rows=32, block_vocab=128)
    want = ref.kd_kl_rowwise(lt, ls)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("temp", [0.5, 1.0, 2.0, 4.0])
def test_temperature(temp):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    _check(jax.random.normal(k1, (40, 200)) * 3,
           jax.random.normal(k2, (40, 200)) * 3, temp=temp, tol=5e-4)


def test_gradient_matches_reference():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    lt = jax.random.normal(k1, (48, 300)) * 2
    ls = jax.random.normal(k2, (48, 300)) * 2
    g = jax.grad(lambda ls: jnp.mean(
        ops.kd_kl_loss(lt, ls, block_rows=16, block_vocab=128)))(ls)
    gr = jax.grad(lambda ls: jnp.mean(ref.kd_kl_rowwise(lt, ls)))(ls)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-6)


def test_teacher_gets_zero_gradient():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    lt = jax.random.normal(k1, (16, 128))
    ls = jax.random.normal(k2, (16, 128))
    g = jax.grad(lambda lt: jnp.mean(
        ops.kd_kl_loss(lt, ls, block_rows=16, block_vocab=128)))(lt)
    assert float(jnp.max(jnp.abs(g))) == 0.0


def test_leading_dims_preserved():
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    lt = jax.random.normal(k1, (2, 3, 5, 64))
    ls = jax.random.normal(k2, (2, 3, 5, 64))
    out = ops.kd_kl_loss(lt, ls, block_rows=8, block_vocab=64)
    assert out.shape == (2, 3, 5)


def test_wired_kd_loss_kl_gradient_masked_ragged():
    """The distillation-layer wiring (kd_loss_kl -> Pallas kernel) must match
    the jnp oracle in value AND gradient on masked, ragged (non-block-
    multiple) rows — the executor's padded-batch hot path."""
    from repro.core import distillation as D
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    t, v = 37, 210                      # ragged vs the 16/64 blocks below
    lt = jax.random.normal(k1, (t, v)) * 3
    ls = jax.random.normal(k2, (t, v)) * 3
    mask = jnp.asarray(np.random.default_rng(0).integers(0, 2, t), jnp.float32)
    gamma, temp = 0.3, 2.0

    def fused(ls):
        return D.kd_loss_kl(lt, ls, gamma, temp, mask=mask, use_pallas=True)

    def oracle(ls):
        return 0.5 * gamma * D.masked_mean(ref.kd_kl_rowwise(lt, ls, temp),
                                           mask)

    lv, gv = jax.value_and_grad(fused)(ls)
    lo, go = jax.value_and_grad(oracle)(ls)
    np.testing.assert_allclose(float(lv), float(lo), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(go),
                               rtol=1e-5, atol=1e-6)
    # the jnp fallback the CPU training path takes must agree too
    lf = D.kd_loss_kl(lt, ls, gamma, temp, mask=mask, use_pallas=False)
    np.testing.assert_allclose(float(lf), float(lo), rtol=1e-6)


def test_wired_kl_divergence_backend_dispatch():
    """kl_divergence routes through ops.kd_kl_loss; on CPU the auto path is
    the jnp oracle (bitwise-identical math to the historical inline KL)."""
    from repro.core import distillation as D
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    lt = jax.random.normal(k1, (5, 4, 33))
    ls = jax.random.normal(k2, (5, 4, 33))
    auto = D.kl_divergence(lt, ls, 1.5)
    want = ref.kd_kl_rowwise(lt, ls, 1.5)
    assert auto.shape == (5, 4)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(want), atol=1e-6)


# ---- properties -----------------------------------------------------------

@sweep(n=15)
def test_property_nonnegative_and_zero_at_equality(rng):
    t = int(rng.integers(1, 64))
    v = int(rng.integers(2, 300))
    lt = jnp.asarray(rng.standard_normal((t, v)) * 5, jnp.float32)
    out = ops.kd_kl_loss(lt, lt, block_rows=16, block_vocab=64)
    assert float(jnp.max(jnp.abs(out))) < 1e-4, "KL(p‖p) must be ~0"
    ls = jnp.asarray(rng.standard_normal((t, v)) * 5, jnp.float32)
    out = ops.kd_kl_loss(lt, ls, block_rows=16, block_vocab=64)
    assert float(jnp.min(out)) >= -1e-5, "KL must be non-negative"


@sweep(n=10)
def test_property_shift_invariance(rng):
    """Adding a constant to all logits of a row changes nothing."""
    t, v = 8, int(rng.integers(4, 200))
    lt = jnp.asarray(rng.standard_normal((t, v)), jnp.float32)
    ls = jnp.asarray(rng.standard_normal((t, v)), jnp.float32)
    c = float(rng.standard_normal()) * 10
    a = ops.kd_kl_loss(lt, ls, block_rows=8, block_vocab=64)
    b = ops.kd_kl_loss(lt + c, ls - c, block_rows=8, block_vocab=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
