"""Multi-host population placement (``repro.population.placement``).

The contract under test:

  * ownership ``host(cid) = shard_of(cid) % n_hosts`` PARTITIONS the
    population — every client has exactly one owner, so the exchanged
    upload lists reassemble without gaps or double-counts;
  * per-host warm caps are ``warm_cap // n_hosts`` and the slab store
    refuses to materialize unowned clients (placement bugs are loud);
  * the filesystem allgather is atomic and self-describing — every host
    decodes byte-identical payloads, including its own;
  * ``n_hosts == 1`` is INERT: bit-for-bit the single-host history, on
    every executor and algorithm, faults and checkpoints included;
  * the real thing: two worker PROCESSES sharing an exchange dir train
    the same global model bit-identically to each other — sync and
    buffered-async, with and without fault injection — and match the
    in-process single-host run, with each host's ``peak_warm`` inside
    its half of the warm cap;
  * a host that dies mid-run degrades to a correlated host fault for the
    survivors, and the coordinated resume restores every host to the
    same round and replays the uninterrupted history bit-for-bit.
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.checkpoint.io import load_flat
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.core.systemsim import FaultProfile
from repro.data.pipeline import ClientData, ClientSlabStore
from repro.population import HostPlacement, Population, allgather
from repro.population.placement import (allgather_partial,
                                        clear_host_payloads, confirm_resume,
                                        publish, resume_barrier)
from repro.sharding import make_array_from_process_local_data_compat

from test_population import _max_param_diff, multidevice

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# --------------------------------------------------------------------------
# HostPlacement: validation / ownership / cap splitting
# --------------------------------------------------------------------------

def test_placement_validation():
    with pytest.raises(ValueError, match="n_hosts"):
        HostPlacement(0, 0)
    with pytest.raises(ValueError, match="out of range"):
        HostPlacement(2, 2, exchange_dir="/tmp/x")
    with pytest.raises(ValueError, match="exchange_dir"):
        HostPlacement(0, 2)                  # multi-host needs the dir
    HostPlacement(0, 1)                      # single host: dir optional


@pytest.mark.parametrize("n_hosts", [1, 2, 3, 5])
def test_ownership_partitions_every_shard(n_hosts):
    placements = [HostPlacement(h, n_hosts, exchange_dir="/tmp/x")
                  for h in range(n_hosts)]
    for shard in range(17):
        owners = [p.owns_shard(shard) for p in placements]
        assert sum(owners) == 1              # exactly one owner, never zero


def test_split_cap():
    p = HostPlacement(0, 2, exchange_dir="/tmp/x")
    assert p.split_cap(None) is None
    assert p.split_cap(16) == 8
    assert p.split_cap(1) == 1               # floor: never a zero cap
    assert HostPlacement(0, 1).split_cap(16) == 16


def test_population_placement_splits_warm_cap(tmp_path):
    pl = HostPlacement(1, 2, exchange_dir=str(tmp_path))
    pop = Population.synthetic(40, warm_cap=16, shard_size=8,
                               min_n=3, max_n=6, placement=pl)
    assert pop.store.warm_cap == 8 and pop.multihost
    # ownership partitions the population across the two host views
    other = Population.synthetic(40, warm_cap=16, shard_size=8,
                                 min_n=3, max_n=6,
                                 placement=HostPlacement(
                                     0, 2, exchange_dir=str(tmp_path)))
    for cid in range(40):
        assert pop.owned(cid) != other.owned(cid)
    # probing shapes must not warm an unowned client
    pop.probe_client()
    assert len(pop.store.warm) == 0


def test_slab_store_refuses_unowned_clients():
    store = ClientSlabStore(owns=lambda cid: cid % 2 == 0)
    dev = jax.devices()[0]
    data = ClientData(np.ones((4, 2), np.float32), np.zeros(4, np.int64))
    store.get(2, data, dev)                  # owned: fine
    with pytest.raises(ValueError, match="not owned"):
        store.get(1, data, dev)


# --------------------------------------------------------------------------
# the filesystem allgather + the process-local-data shim
# --------------------------------------------------------------------------

def test_allgather_roundtrip(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(tmp_path), timeout_s=10)
    mine = {"idx": [0, 2], "uploads": [np.arange(6, dtype=np.float32),
                                      np.eye(2)],
            "weights": [1.5, 2.0], "stats": {"peak_warm": 3}}
    theirs = {"idx": [1], "uploads": [np.full((3,), 7.0)],
              "weights": [0.5], "stats": {"peak_warm": 2}}
    publish(p1, "round000000", theirs)       # peer already landed
    got = allgather(p0, "round000000", mine)
    assert len(got) == 2
    # this host's payload round-trips through ITS OWN file too
    np.testing.assert_array_equal(got[0]["uploads"][0], mine["uploads"][0])
    assert got[0]["uploads"][0].dtype == np.float32
    assert got[0]["idx"] == [0, 2] and got[0]["weights"] == [1.5, 2.0]
    np.testing.assert_array_equal(got[1]["uploads"][0], theirs["uploads"][0])
    assert got[1]["stats"]["peak_warm"] == 2


def test_allgather_times_out_naming_missing_hosts_and_tag(tmp_path):
    # the error must name EVERY missing host and the exchange tag — on a
    # real topology that is the difference between restarting one worker
    # and hunting a deadlock
    p0 = HostPlacement(0, 3, exchange_dir=str(tmp_path), timeout_s=0.2)
    with pytest.raises(RuntimeError,
                       match=r"'round000001'.*host\(s\) \[1, 2\]"):
        allgather(p0, "round000001", {"idx": []})
    assert p0.stats["timeouts"] == 1
    assert p0.stats["last_missing"] == [1, 2]
    assert p0.stats["last_missing_tag"] == "round000001"


def test_allgather_partial_degrades_and_skips_dead_hosts(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=0.2)
    payloads, missing = allgather_partial(p0, "wave000000000", {"x": 1})
    assert missing == (1,)
    assert payloads[1] is None and payloads[0]["x"] == 1
    # a peer already declared dead costs one existence check, not a
    # full timeout, on every subsequent exchange
    p1 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=60)
    t0 = time.monotonic()
    payloads, missing = allgather_partial(p1, "wave000000001", {"x": 2},
                                          skip_wait={1})
    assert missing == (1,) and payloads[0]["x"] == 2
    assert time.monotonic() - t0 < 10


def test_resume_barrier_agrees_on_min_round(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(tmp_path), timeout_s=10)
    publish(p1, "resume-avail", {"avail": 7})    # peer got further ahead
    assert resume_barrier(p0, 3) == 3
    # and the slower host's view agrees
    assert resume_barrier(p1, 7) == 3


def test_resume_barrier_all_fresh_and_mixed(tmp_path):
    fresh = tmp_path / "fresh"
    p0 = HostPlacement(0, 2, exchange_dir=str(fresh), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(fresh), timeout_s=10)
    publish(p1, "resume-avail", {"avail": None})
    assert resume_barrier(p0, None) is None      # everyone starts fresh
    mixed = tmp_path / "mixed"
    p0 = HostPlacement(0, 2, exchange_dir=str(mixed), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(mixed), timeout_s=10)
    publish(p1, "resume-avail", {"avail": None})
    with pytest.raises(RuntimeError, match="mixed fresh/resume"):
        resume_barrier(p0, 4)


def test_confirm_resume_validates_and_retires_phase1(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(tmp_path), timeout_s=10)
    publish(p0, "resume-avail", {"avail": 3})
    meta = {"round": 3, "version": 9, "algo": "fedavg"}
    publish(p1, "resume-ok-r000003", dict(meta))
    confirm_resume(p0, 3, meta)                  # peers agree: fine
    # completing the barrier retires this host's phase-1 file
    assert not os.path.exists(str(tmp_path / "resume-avail_host000.npz"))
    # a peer that restored DIFFERENT state fails loudly before any wave
    publish(p1, "resume-ok-r000004", {"round": 4, "version": 9,
                                      "algo": "fedavg"})
    with pytest.raises(RuntimeError, match="diverged"):
        confirm_resume(p0, 4, {"round": 4, "version": 11, "algo": "fedavg"})


def test_clear_host_payloads_removes_own_wave_files_only(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(tmp_path), timeout_s=10)
    publish(p0, "wave000000004", {"x": 1})
    publish(p0, "round000002a01", {"x": 2})
    publish(p0, "resume-avail", {"avail": 2})
    publish(p1, "wave000000004", {"x": 3})
    assert clear_host_payloads(p0) == 2          # own wave/round files only
    left = sorted(os.listdir(tmp_path))
    assert left == ["resume-avail_host000.npz", "wave000000004_host001.npz"]


def test_make_array_from_process_local_data_shim_single_device():
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    out = make_array_from_process_local_data_compat(sharding, x)
    np.testing.assert_array_equal(np.asarray(out), x)
    assert out.sharding.is_equivalent_to(sharding, x.ndim)


@multidevice
def test_make_array_shim_matches_device_put_on_mesh():
    from repro.launch.mesh import make_clients_mesh

    mesh = make_clients_mesh()
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("clients"))
    n = len(jax.devices())
    x = np.arange(n * 3, dtype=np.float32).reshape(n, 3)
    out = make_array_from_process_local_data_compat(sharding, x)
    ref = jax.device_put(x, sharding)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------------------------------------------
# n_hosts == 1 is inert: bit-for-bit the single-host history
# --------------------------------------------------------------------------

def _tiny_task():
    return dataclasses.replace(TOY, n_clients=12, participation=0.25,
                               rounds=2, local_epochs=1, batch_size=8)


def _tiny_pop(placement=None):
    return Population.synthetic(12, warm_cap=8, shard_size=4, min_n=5,
                                max_n=9, placement=placement)


@pytest.mark.parametrize("name", ["fedavg", "fedgkd"])
@pytest.mark.parametrize("spec", ["sequential", "vmap", "async"])
def test_n_hosts_1_bit_identical(name, spec):
    task = _tiny_task()
    h0 = fl_loop.run_federated(task, algorithms.make(name),
                               population=_tiny_pop(), seed=0,
                               executor=spec, width=4)
    h1 = fl_loop.run_federated(task, algorithms.make(name),
                               population=_tiny_pop(HostPlacement(0, 1)),
                               seed=0, executor=spec, width=4)
    assert _max_param_diff(h0.final_params, h1.final_params) == 0.0
    for r0, r1 in zip(h0.records, h1.records):
        assert r0.sampled == r1.sampled
        assert r0.mean_local_loss == r1.mean_local_loss


@multidevice
def test_n_hosts_1_bit_identical_shard_map():
    task = _tiny_task()
    h0 = fl_loop.run_federated(task, algorithms.make("fedgkd"),
                               population=_tiny_pop(), seed=0,
                               executor="shard_map", width=4)
    h1 = fl_loop.run_federated(task, algorithms.make("fedgkd"),
                               population=_tiny_pop(HostPlacement(0, 1)),
                               seed=0, executor="shard_map", width=4)
    assert _max_param_diff(h0.final_params, h1.final_params) == 0.0


def test_multihost_rejects_unsupported_compositions(tmp_path):
    # async / faults / checkpointing all compose with placement now —
    # only differential privacy is still fenced off
    from repro.core.privacy import DPConfig
    task = _tiny_task()
    algo = algorithms.make("fedavg")
    pop = _tiny_pop(HostPlacement(0, 2, exchange_dir=str(tmp_path),
                                  timeout_s=1))
    with pytest.raises(NotImplementedError, match="dp"):
        fl_loop.run_federated(task, algo, population=pop, seed=0,
                              executor="vmap", width=4, dp=DPConfig())


def test_n_hosts_1_inert_with_faults_and_checkpoint(tmp_path):
    # host_crash_prob only ever draws under a real multi-host placement:
    # an n_hosts=1 run with a nonzero probability must replay the exact
    # single-host fault stream (and write the same ``state_`` checkpoints)
    task = _tiny_task()
    kw = dict(seed=0, executor="async", width=4, checkpoint_every=1,
              faults=FaultProfile(crash_prob=0.2, corrupt_prob=0.2,
                                  host_crash_prob=0.5))
    h0 = fl_loop.run_federated(task, algorithms.make("fedavg"),
                               population=_tiny_pop(),
                               checkpoint_dir=str(tmp_path / "a"), **kw)
    h1 = fl_loop.run_federated(task, algorithms.make("fedavg"),
                               population=_tiny_pop(HostPlacement(0, 1)),
                               checkpoint_dir=str(tmp_path / "b"), **kw)
    assert _max_param_diff(h0.final_params, h1.final_params) == 0.0
    assert h1.telemetry["faults"]["host_crashes"] == 0
    assert sorted(os.listdir(tmp_path / "a")) == \
        sorted(os.listdir(tmp_path / "b"))
    assert any(f.startswith("state_0") for f in os.listdir(tmp_path / "b"))


# --------------------------------------------------------------------------
# the real thing: 2 worker processes over a shared exchange dir
# --------------------------------------------------------------------------

_WORKER = """\
import dataclasses, json, os, sys
import numpy as np
host, n_hosts = int(sys.argv[1]), int(sys.argv[2])
exch, out, algo_name, spec = sys.argv[3], sys.argv[4], sys.argv[5], sys.argv[6]
cfg = json.loads(sys.argv[7]) if len(sys.argv) > 7 else {}
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.population import Population, HostPlacement
from repro.checkpoint.io import save_pytree
import jax
pl = HostPlacement(host, n_hosts, exchange_dir=exch,
                   timeout_s=cfg.get("timeout_s", 180))
pop = Population.synthetic(50, warm_cap=32, shard_size=4, min_n=5, max_n=9,
                           placement=pl)
task = dataclasses.replace(TOY, n_clients=50, participation=0.2,
                           rounds=cfg.get("rounds", 2), local_epochs=1,
                           batch_size=8)
kw = {}
if cfg.get("faults"):
    from repro.core.systemsim import FaultProfile
    kw["faults"] = FaultProfile(**cfg["faults"])
if cfg.get("checkpoint_dir"):
    kw["checkpoint_dir"] = cfg["checkpoint_dir"]
    kw["resume"] = bool(cfg.get("resume"))
die_at = cfg.get("die_at_round")
if die_at is not None and host == cfg.get("die_host", 0):
    # hard host kill right AFTER that round's checkpoint was cut (the
    # callback runs after save_ckpt): no cleanup, no exchange goodbye
    kw["round_callback"] = (
        lambda rnd, server, model: os._exit(17) if rnd == die_at else None)
h = fl_loop.run_federated(task, algorithms.make(algo_name), population=pop,
                          seed=0, executor=spec, width=4, **kw)
stats = h.telemetry["population"]
flat = {f"p{i:03d}": np.asarray(x)
        for i, x in enumerate(jax.tree_util.tree_leaves(h.final_params))}
flat["acc"] = np.float64(h.final_acc)
flat["peak_warm"] = np.int64(stats["peak_warm"])
flat["warm_cap"] = np.int64(stats["warm_cap"])
flat["n_host_stats"] = np.int64(len(stats.get("hosts") or []))
flat["accs"] = np.asarray([r.test_acc for r in h.records], np.float64)
flat["losses"] = np.asarray([r.mean_local_loss for r in h.records],
                            np.float64)
flat["sampled"] = np.asarray(
    [c for r in h.records for c in (*(r.sampled or ()), -1)], np.int64)
ft = h.telemetry.get("faults") or {}
for key in ("host_crashes", "host_timeouts", "crashes", "corrupt_injected",
            "retries", "dropped_clients", "quorum_shortfalls"):
    flat["f_" + key] = np.int64(ft.get(key, -1))
save_pytree(out, flat)
"""

def _spawn_workers(tmp_path, algo, spec, n_hosts=2, xla_flags=None,
                   cfg=None, hosts=None, expect_rc=None, exch=None,
                   timeout=600):
    tmp_path.mkdir(parents=True, exist_ok=True)
    worker = tmp_path / "worker.py"
    worker.write_text(_WORKER)
    exch = str(tmp_path / "exchange") if exch is None else exch
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    if xla_flags:
        env["XLA_FLAGS"] = xla_flags
    hosts = list(range(n_hosts)) if hosts is None else list(hosts)
    outs = {h: str(tmp_path / f"host{h}.npz") for h in hosts}
    extra = [json.dumps(cfg)] if cfg else []
    procs = {h: subprocess.Popen(
        [sys.executable, str(worker), str(h), str(n_hosts), exch,
         outs[h], algo, spec, *extra],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        for h in hosts}
    for h, p in procs.items():
        out, _ = p.communicate(timeout=timeout)
        want = 0 if expect_rc is None else expect_rc.get(h, 0)
        assert p.returncode == want, (
            f"host {h} worker exited {p.returncode} (wanted {want}):\n{out}")
    return [load_flat(outs[h]) for h in hosts if os.path.exists(outs[h])]


def _assert_hosts_identical(h0, h1):
    """Both hosts' outputs must agree BITWISE — they consumed
    byte-identical exchange inputs and replayed the same simulation.
    ``peak_warm`` is the one per-host value (each host warms only its
    owned slice)."""
    for k in sorted(h0):
        if k != "peak_warm":
            np.testing.assert_array_equal(h0[k], h1[k], err_msg=k)


def _param_diff_vs(ref_history, flat):
    keys = sorted(k for k in flat if k.startswith("p"))
    leaves = jax.tree_util.tree_leaves(ref_history.final_params)
    return max(float(np.max(np.abs(np.asarray(x) - flat[k])))
               for k, x in zip(keys, leaves))


def _reference_history(algo, spec, rounds=2, **kw):
    task = dataclasses.replace(TOY, n_clients=50, participation=0.2,
                               rounds=rounds, local_epochs=1, batch_size=8)
    pop = Population.synthetic(50, warm_cap=32, shard_size=4, min_n=5,
                               max_n=9)
    return fl_loop.run_federated(task, algorithms.make(algo),
                                 population=pop, seed=0, executor=spec,
                                 width=4, **kw)


@pytest.mark.parametrize("algo", ["fedavg", "fedgkd"])
def test_two_process_run_matches_single_host(tmp_path, algo):
    """The tentpole acceptance: two processes, shared exchange dir, each
    owning half the shards — identical global params on both hosts,
    matching the single-host run, with per-host ``peak_warm`` inside its
    half of the global warm cap."""
    h0, h1 = _spawn_workers(tmp_path, algo, "vmap")
    keys = sorted(k for k in h0 if k.startswith("p"))
    # hosts agree bitwise: they aggregated byte-identical exchange inputs
    for k in keys:
        np.testing.assert_array_equal(h0[k], h1[k])
    assert float(h0["acc"]) == float(h1["acc"])
    # telemetry aggregated from BOTH hosts on each host
    assert int(h0["n_host_stats"]) == 2 and int(h1["n_host_stats"]) == 2
    # each host stayed inside its half of the global cap (32 // 2 = 16)
    for flat in (h0, h1):
        assert int(flat["warm_cap"]) == 16
        assert int(flat["peak_warm"]) <= 16
    # and the distributed run matches the single-host history
    ref = _reference_history(algo, "vmap")
    leaves = jax.tree_util.tree_leaves(ref.final_params)
    diff = max(float(np.max(np.abs(np.asarray(x) - h0[k])))
               for k, x in zip(keys, leaves))
    assert diff < 1e-5                       # measured 0.0 on CPU


def test_two_process_async_matches_single_host(tmp_path):
    """Async wave protocol under placement: two processes, per-wave
    exchange tags, each training only its owned slice of the wave's fixed
    slots — both hosts replay the identical simulation (clock, versions,
    aggregation membership) and match the single-host async run."""
    h0, h1 = _spawn_workers(tmp_path, "fedavg", "async")
    _assert_hosts_identical(h0, h1)
    assert int(h0["n_host_stats"]) == 2
    for flat in (h0, h1):
        assert int(flat["peak_warm"]) <= 16
    ref = _reference_history("fedavg", "async")
    assert _param_diff_vs(ref, h0) < 1e-5        # measured 0.0 on CPU
    # the aggregation membership (completions per round) is identical —
    # the event heaps never diverged from the single-host simulation
    ref_sampled = np.asarray(
        [c for r in ref.records for c in (*(r.sampled or ()), -1)],
        np.int64)
    np.testing.assert_array_equal(h0["sampled"], ref_sampled)


def test_two_process_async_faults_bit_identical(tmp_path):
    """Correlated host faults: with ``host_crash_prob`` on, whole owned
    slices fail as a block, yet both hosts draw the same fault stream and
    stay bitwise in lockstep through retries and re-dispatches."""
    cfg = {"rounds": 3, "faults": {"crash_prob": 0.1, "corrupt_prob": 0.1,
                                   "timeout_prob": 0.05,
                                   "host_crash_prob": 0.3}}
    h0, h1 = _spawn_workers(tmp_path, "fedavg", "async", cfg=cfg)
    _assert_hosts_identical(h0, h1)
    assert int(h0["f_host_crashes"]) > 0         # injection actually fired
    assert int(h0["f_host_timeouts"]) == 0       # nobody really died


def test_two_process_sync_faults_match_single_host(tmp_path):
    """With ``host_crash_prob == 0`` the placement-aware fault round
    consumes the fault/pick streams exactly like the single-host
    ``_fault_tolerant_round`` — same survivors, same retries, same
    aggregate."""
    cfg = {"faults": {"crash_prob": 0.2, "corrupt_prob": 0.2}}
    h0, h1 = _spawn_workers(tmp_path, "fedavg", "vmap", cfg=cfg)
    _assert_hosts_identical(h0, h1)
    ref = _reference_history("fedavg", "vmap",
                             faults=FaultProfile(crash_prob=0.2,
                                                 corrupt_prob=0.2))
    assert _param_diff_vs(ref, h0) < 1e-5
    assert int(h0["f_crashes"]) == ref.telemetry["faults"]["crashes"]
    assert int(h0["f_retries"]) == ref.telemetry["faults"]["retries"]


def test_sync_deadline_miss_degrades_to_host_crash(tmp_path):
    """Host 1 is never spawned: with fault tolerance on, the survivor
    treats the missed exchange deadline as a crashed peer (a correlated
    fault over its whole slice, not a hang) and completes on its own
    validated uploads."""
    cfg = {"timeout_s": 3, "faults": {"crash_prob": 0.05}}
    (h0,) = _spawn_workers(tmp_path, "fedavg", "vmap", cfg=cfg, hosts=[0])
    assert int(h0["f_host_timeouts"]) == 1       # declared dead ONCE, then
    assert np.isfinite(float(h0["acc"]))         # skipped, never re-polled


@pytest.mark.slow
def test_kill_one_host_then_coordinated_resume_bit_identical(tmp_path):
    """The recovery acceptance: hard-kill host 0 right after round 2's
    checkpoint (host 1 degrades and runs ahead alone), then restart BOTH
    hosts with ``resume=True`` — the resume barrier agrees on round 2
    (min over hosts), host 1 abandons its degraded solo tail, stale wave
    exchange files are retired, and the replayed history is bit-identical
    to the uninterrupted 2-host run, faults included."""
    cfg = {"rounds": 4, "timeout_s": 20,
           "faults": {"crash_prob": 0.1, "corrupt_prob": 0.1,
                      "host_crash_prob": 0.2}}
    r0, r1 = _spawn_workers(tmp_path / "ref", "fedavg", "async",
                            cfg={**cfg, "checkpoint_dir":
                                 str(tmp_path / "ck_ref")})
    _assert_hosts_identical(r0, r1)

    ck = str(tmp_path / "ck")
    kill = tmp_path / "kill"
    got = _spawn_workers(kill, "fedavg", "async",
                         cfg={**cfg, "checkpoint_dir": ck,
                              "die_at_round": 2, "die_host": 0},
                         expect_rc={0: 17})
    assert len(got) == 1                         # only host 1 finished
    assert int(got[0]["f_host_timeouts"]) == 1   # it saw host 0 die
    # host 1 checkpointed past the kill point; host 0 stopped at round 2
    assert os.path.exists(os.path.join(ck, "state_host001_000004.npz"))
    assert not os.path.exists(os.path.join(ck, "state_host000_000003.npz"))

    # coordinated restart over the SAME exchange dir (stale wave payloads
    # from the degraded solo run must be retired, not trusted)
    o0, o1 = _spawn_workers(tmp_path / "res", "fedavg", "async",
                            cfg={**cfg, "checkpoint_dir": ck,
                                 "resume": True},
                            exch=str(kill / "exchange"))
    _assert_hosts_identical(o0, o1)
    for k in sorted(r0):
        if k != "peak_warm":
            np.testing.assert_array_equal(o0[k], r0[k], err_msg=k)


@pytest.mark.slow
def test_two_process_shard_map_run(tmp_path):
    """2 processes × 8 forced host devices each, shard_map route: the
    cohort slice shards over each host's LOCAL device mesh and the
    result still matches the single-host shard_map run."""
    h0, h1 = _spawn_workers(
        tmp_path, "fedavg", "shard_map",
        xla_flags="--xla_force_host_platform_device_count=8")
    keys = sorted(k for k in h0 if k.startswith("p"))
    for k in keys:
        np.testing.assert_array_equal(h0[k], h1[k])
    assert int(h0["peak_warm"]) <= 16


# --------------------------------------------------------------------------
# leaving the emulator: a real jax.distributed topology
# --------------------------------------------------------------------------

_DIST_WORKER = """\
import dataclasses, sys
import numpy as np
rank, n, coord = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
exch, out, spec = sys.argv[4], sys.argv[5], sys.argv[6]
from repro.launch import distributed
info = distributed.initialize(coord, n, rank)
assert info["process_count"] == n, info
import jax
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.population import Population
from repro.checkpoint.io import save_pytree
pl = distributed.placement_from_runtime(exch, timeout_s=180)
assert (pl.host_id, pl.n_hosts) == (rank, n)
pop = Population.synthetic(50, warm_cap=32, shard_size=4, min_n=5, max_n=9,
                           placement=pl)
task = dataclasses.replace(TOY, n_clients=50, participation=0.2, rounds=2,
                           local_epochs=1, batch_size=8)
h = fl_loop.run_federated(task, algorithms.make("fedavg"), population=pop,
                          seed=0, executor=spec, width=4)
flat = {f"p{i:03d}": np.asarray(x)
        for i, x in enumerate(jax.tree_util.tree_leaves(h.final_params))}
flat["acc"] = np.float64(h.final_acc)
flat["procs"] = np.int64(info["process_count"])
flat["global_devices"] = np.int64(info["global_devices"])
save_pytree(out, flat)
"""


def _spawn_distributed(tmp_path, spec, xla_flags=None, timeout=600):
    from repro.launch.distributed import find_free_port

    worker = tmp_path / "dist_worker.py"
    worker.write_text(_DIST_WORKER)
    coord = f"127.0.0.1:{find_free_port()}"
    exch = str(tmp_path / "exchange")
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    if xla_flags:
        env["XLA_FLAGS"] = xla_flags
    outs = [str(tmp_path / f"rank{r}.npz") for r in range(2)]
    procs = [subprocess.Popen(
        [sys.executable, str(worker), str(r), "2", coord, exch,
         outs[r], spec], env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True) for r in range(2)]
    for r, p in enumerate(procs):
        out, _ = p.communicate(timeout=timeout)
        assert p.returncode == 0, f"rank {r} worker failed:\n{out}"
    return [load_flat(o) for o in outs]


def test_distributed_global_array_stitch():
    """A REAL 2-process ``jax.distributed`` topology on CPU (gloo): the
    smoke CLI stitches a global array from process-local shards — the
    non-fallback branch of ``make_array_from_process_local_data_compat``,
    unreachable single-process — and every rank sums it identically."""
    from repro.launch.distributed import find_free_port

    coord = f"127.0.0.1:{find_free_port()}"
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.distributed",
         "--coordinator", coord, "--num-processes", "2",
         "--process-id", str(r)], env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True) for r in range(2)]
    for r, p in enumerate(procs):
        out, _ = p.communicate(timeout=300)
        assert p.returncode == 0, f"rank {r} smoke failed:\n{out}"
        assert "global_devices=4" in out


def test_distributed_two_process_fl_run(tmp_path):
    """The multi-host federated loop on a live ``jax.distributed``
    topology, placement derived from ``jax.process_index()`` — identical
    params on both ranks, matching the single-host run."""
    h0, h1 = _spawn_distributed(tmp_path, "vmap")
    assert int(h0["procs"]) == 2 and int(h0["global_devices"]) == 2
    for k in sorted(h0):
        np.testing.assert_array_equal(h0[k], h1[k], err_msg=k)
    ref = _reference_history("fedavg", "vmap")
    assert _param_diff_vs(ref, h0) < 1e-5


@pytest.mark.slow
def test_distributed_shard_map_local_mesh(tmp_path):
    """2 ranks × 2 forced host devices: the shard_map executor detects
    ``jax.process_count() > 1`` and shards each rank's cohort slice over
    its LOCAL device mesh (``make_local_clients_mesh``)."""
    h0, h1 = _spawn_distributed(
        tmp_path, "shard_map",
        xla_flags="--xla_force_host_platform_device_count=2")
    assert int(h0["global_devices"]) == 4
    for k in sorted(h0):
        np.testing.assert_array_equal(h0[k], h1[k], err_msg=k)
