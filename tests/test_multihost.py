"""Multi-host population placement (``repro.population.placement``).

The contract under test:

  * ownership ``host(cid) = shard_of(cid) % n_hosts`` PARTITIONS the
    population — every client has exactly one owner, so the exchanged
    upload lists reassemble without gaps or double-counts;
  * per-host warm caps are ``warm_cap // n_hosts`` and the slab store
    refuses to materialize unowned clients (placement bugs are loud);
  * the filesystem allgather is atomic and self-describing — every host
    decodes byte-identical payloads, including its own;
  * ``n_hosts == 1`` is INERT: bit-for-bit the single-host history, on
    every executor and algorithm;
  * the real thing: two worker PROCESSES sharing an exchange dir train
    the same global model bit-identically to each other and match the
    in-process single-host run, with each host's ``peak_warm`` inside
    its half of the warm cap.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint.io import load_flat
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.core.systemsim import FaultProfile
from repro.data.pipeline import ClientData, ClientSlabStore
from repro.population import HostPlacement, Population, allgather
from repro.population.placement import publish
from repro.sharding import make_array_from_process_local_data_compat

from test_population import _max_param_diff, multidevice

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# --------------------------------------------------------------------------
# HostPlacement: validation / ownership / cap splitting
# --------------------------------------------------------------------------

def test_placement_validation():
    with pytest.raises(ValueError, match="n_hosts"):
        HostPlacement(0, 0)
    with pytest.raises(ValueError, match="out of range"):
        HostPlacement(2, 2, exchange_dir="/tmp/x")
    with pytest.raises(ValueError, match="exchange_dir"):
        HostPlacement(0, 2)                  # multi-host needs the dir
    HostPlacement(0, 1)                      # single host: dir optional


@pytest.mark.parametrize("n_hosts", [1, 2, 3, 5])
def test_ownership_partitions_every_shard(n_hosts):
    placements = [HostPlacement(h, n_hosts, exchange_dir="/tmp/x")
                  for h in range(n_hosts)]
    for shard in range(17):
        owners = [p.owns_shard(shard) for p in placements]
        assert sum(owners) == 1              # exactly one owner, never zero


def test_split_cap():
    p = HostPlacement(0, 2, exchange_dir="/tmp/x")
    assert p.split_cap(None) is None
    assert p.split_cap(16) == 8
    assert p.split_cap(1) == 1               # floor: never a zero cap
    assert HostPlacement(0, 1).split_cap(16) == 16


def test_population_placement_splits_warm_cap(tmp_path):
    pl = HostPlacement(1, 2, exchange_dir=str(tmp_path))
    pop = Population.synthetic(40, warm_cap=16, shard_size=8,
                               min_n=3, max_n=6, placement=pl)
    assert pop.store.warm_cap == 8 and pop.multihost
    # ownership partitions the population across the two host views
    other = Population.synthetic(40, warm_cap=16, shard_size=8,
                                 min_n=3, max_n=6,
                                 placement=HostPlacement(
                                     0, 2, exchange_dir=str(tmp_path)))
    for cid in range(40):
        assert pop.owned(cid) != other.owned(cid)
    # probing shapes must not warm an unowned client
    pop.probe_client()
    assert len(pop.store.warm) == 0


def test_slab_store_refuses_unowned_clients():
    store = ClientSlabStore(owns=lambda cid: cid % 2 == 0)
    dev = jax.devices()[0]
    data = ClientData(np.ones((4, 2), np.float32), np.zeros(4, np.int64))
    store.get(2, data, dev)                  # owned: fine
    with pytest.raises(ValueError, match="not owned"):
        store.get(1, data, dev)


# --------------------------------------------------------------------------
# the filesystem allgather + the process-local-data shim
# --------------------------------------------------------------------------

def test_allgather_roundtrip(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=10)
    p1 = HostPlacement(1, 2, exchange_dir=str(tmp_path), timeout_s=10)
    mine = {"idx": [0, 2], "uploads": [np.arange(6, dtype=np.float32),
                                      np.eye(2)],
            "weights": [1.5, 2.0], "stats": {"peak_warm": 3}}
    theirs = {"idx": [1], "uploads": [np.full((3,), 7.0)],
              "weights": [0.5], "stats": {"peak_warm": 2}}
    publish(p1, "round000000", theirs)       # peer already landed
    got = allgather(p0, "round000000", mine)
    assert len(got) == 2
    # this host's payload round-trips through ITS OWN file too
    np.testing.assert_array_equal(got[0]["uploads"][0], mine["uploads"][0])
    assert got[0]["uploads"][0].dtype == np.float32
    assert got[0]["idx"] == [0, 2] and got[0]["weights"] == [1.5, 2.0]
    np.testing.assert_array_equal(got[1]["uploads"][0], theirs["uploads"][0])
    assert got[1]["stats"]["peak_warm"] == 2


def test_allgather_times_out_naming_missing_host(tmp_path):
    p0 = HostPlacement(0, 2, exchange_dir=str(tmp_path), timeout_s=0.2)
    with pytest.raises(RuntimeError, match="host 1"):
        allgather(p0, "round000001", {"idx": []})


def test_make_array_from_process_local_data_shim_single_device():
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    out = make_array_from_process_local_data_compat(sharding, x)
    np.testing.assert_array_equal(np.asarray(out), x)
    assert out.sharding.is_equivalent_to(sharding, x.ndim)


@multidevice
def test_make_array_shim_matches_device_put_on_mesh():
    from repro.launch.mesh import make_clients_mesh

    mesh = make_clients_mesh()
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("clients"))
    n = len(jax.devices())
    x = np.arange(n * 3, dtype=np.float32).reshape(n, 3)
    out = make_array_from_process_local_data_compat(sharding, x)
    ref = jax.device_put(x, sharding)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------------------------------------------
# n_hosts == 1 is inert: bit-for-bit the single-host history
# --------------------------------------------------------------------------

def _tiny_task():
    return dataclasses.replace(TOY, n_clients=12, participation=0.25,
                               rounds=2, local_epochs=1, batch_size=8)


def _tiny_pop(placement=None):
    return Population.synthetic(12, warm_cap=8, shard_size=4, min_n=5,
                                max_n=9, placement=placement)


@pytest.mark.parametrize("name", ["fedavg", "fedgkd"])
@pytest.mark.parametrize("spec", ["sequential", "vmap", "async"])
def test_n_hosts_1_bit_identical(name, spec):
    task = _tiny_task()
    h0 = fl_loop.run_federated(task, algorithms.make(name),
                               population=_tiny_pop(), seed=0,
                               executor=spec, width=4)
    h1 = fl_loop.run_federated(task, algorithms.make(name),
                               population=_tiny_pop(HostPlacement(0, 1)),
                               seed=0, executor=spec, width=4)
    assert _max_param_diff(h0.final_params, h1.final_params) == 0.0
    for r0, r1 in zip(h0.records, h1.records):
        assert r0.sampled == r1.sampled
        assert r0.mean_local_loss == r1.mean_local_loss


@multidevice
def test_n_hosts_1_bit_identical_shard_map():
    task = _tiny_task()
    h0 = fl_loop.run_federated(task, algorithms.make("fedgkd"),
                               population=_tiny_pop(), seed=0,
                               executor="shard_map", width=4)
    h1 = fl_loop.run_federated(task, algorithms.make("fedgkd"),
                               population=_tiny_pop(HostPlacement(0, 1)),
                               seed=0, executor="shard_map", width=4)
    assert _max_param_diff(h0.final_params, h1.final_params) == 0.0


def test_multihost_rejects_unsupported_compositions(tmp_path):
    task = _tiny_task()
    algo = algorithms.make("fedavg")

    def pop():
        return _tiny_pop(HostPlacement(0, 2, exchange_dir=str(tmp_path),
                                       timeout_s=1))

    with pytest.raises(NotImplementedError, match="async"):
        fl_loop.run_federated(task, algo, population=pop(), seed=0,
                              executor="async", width=4)
    with pytest.raises(NotImplementedError, match="faults"):
        fl_loop.run_federated(task, algo, population=pop(), seed=0,
                              executor="vmap", width=4,
                              faults=FaultProfile(crash_prob=0.5))
    with pytest.raises(NotImplementedError, match="checkpoint_dir"):
        fl_loop.run_federated(task, algo, population=pop(), seed=0,
                              executor="vmap", width=4,
                              checkpoint_dir=str(tmp_path / "ckpt"))


# --------------------------------------------------------------------------
# the real thing: 2 worker processes over a shared exchange dir
# --------------------------------------------------------------------------

_WORKER = """\
import dataclasses, sys
import numpy as np
host, n_hosts = int(sys.argv[1]), int(sys.argv[2])
exch, out, algo_name, spec = sys.argv[3], sys.argv[4], sys.argv[5], sys.argv[6]
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.population import Population, HostPlacement
from repro.checkpoint.io import save_pytree
import jax
pl = HostPlacement(host, n_hosts, exchange_dir=exch, timeout_s=180)
pop = Population.synthetic(50, warm_cap=32, shard_size=4, min_n=5, max_n=9,
                           placement=pl)
task = dataclasses.replace(TOY, n_clients=50, participation=0.2, rounds=2,
                           local_epochs=1, batch_size=8)
h = fl_loop.run_federated(task, algorithms.make(algo_name), population=pop,
                          seed=0, executor=spec, width=4)
stats = h.telemetry["population"]
flat = {f"p{i:03d}": np.asarray(x)
        for i, x in enumerate(jax.tree_util.tree_leaves(h.final_params))}
flat["acc"] = np.float64(h.final_acc)
flat["peak_warm"] = np.int64(stats["peak_warm"])
flat["warm_cap"] = np.int64(stats["warm_cap"])
flat["n_host_stats"] = np.int64(len(stats["hosts"]))
save_pytree(out, flat)
"""


def _spawn_workers(tmp_path, algo, spec, n_hosts=2, xla_flags=None):
    worker = tmp_path / "worker.py"
    worker.write_text(_WORKER)
    exch = tmp_path / "exchange"
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    if xla_flags:
        env["XLA_FLAGS"] = xla_flags
    outs = [str(tmp_path / f"host{h}.npz") for h in range(n_hosts)]
    procs = [subprocess.Popen(
        [sys.executable, str(worker), str(h), str(n_hosts), str(exch),
         outs[h], algo, spec],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        for h in range(n_hosts)]
    for h, p in enumerate(procs):
        out, _ = p.communicate(timeout=600)
        assert p.returncode == 0, f"host {h} worker failed:\n{out}"
    return [load_flat(o) for o in outs]


def _reference_history(algo, spec):
    task = dataclasses.replace(TOY, n_clients=50, participation=0.2,
                               rounds=2, local_epochs=1, batch_size=8)
    pop = Population.synthetic(50, warm_cap=32, shard_size=4, min_n=5,
                               max_n=9)
    return fl_loop.run_federated(task, algorithms.make(algo),
                                 population=pop, seed=0, executor=spec,
                                 width=4)


@pytest.mark.parametrize("algo", ["fedavg", "fedgkd"])
def test_two_process_run_matches_single_host(tmp_path, algo):
    """The tentpole acceptance: two processes, shared exchange dir, each
    owning half the shards — identical global params on both hosts,
    matching the single-host run, with per-host ``peak_warm`` inside its
    half of the global warm cap."""
    h0, h1 = _spawn_workers(tmp_path, algo, "vmap")
    keys = sorted(k for k in h0 if k.startswith("p"))
    # hosts agree bitwise: they aggregated byte-identical exchange inputs
    for k in keys:
        np.testing.assert_array_equal(h0[k], h1[k])
    assert float(h0["acc"]) == float(h1["acc"])
    # telemetry aggregated from BOTH hosts on each host
    assert int(h0["n_host_stats"]) == 2 and int(h1["n_host_stats"]) == 2
    # each host stayed inside its half of the global cap (32 // 2 = 16)
    for flat in (h0, h1):
        assert int(flat["warm_cap"]) == 16
        assert int(flat["peak_warm"]) <= 16
    # and the distributed run matches the single-host history
    ref = _reference_history(algo, "vmap")
    leaves = jax.tree_util.tree_leaves(ref.final_params)
    diff = max(float(np.max(np.abs(np.asarray(x) - h0[k])))
               for k, x in zip(keys, leaves))
    assert diff < 1e-5                       # measured 0.0 on CPU


@pytest.mark.slow
def test_two_process_shard_map_run(tmp_path):
    """2 processes × 8 forced host devices each, shard_map route: the
    cohort slice shards over each host's LOCAL device mesh and the
    result still matches the single-host shard_map run."""
    h0, h1 = _spawn_workers(
        tmp_path, "fedavg", "shard_map",
        xla_flags="--xla_force_host_platform_device_count=8")
    keys = sorted(k for k in h0 if k.startswith("p"))
    for k in keys:
        np.testing.assert_array_equal(h0[k], h1[k])
    assert int(h0["peak_warm"]) <= 16
