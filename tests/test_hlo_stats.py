"""HLO collective parser: handcrafted text + a real compiled artifact."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_stats

SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = bf16[128,1024]{1,0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %ag = bf16[512,1024]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[64]{0} all-reduce(%p1), to_apply=%add
  %rs = bf16[32,1024]{1,0} reduce-scatter(%p0), dimensions={0}
  %cp = bf16[128,1024]{1,0} collective-permute(%p0)
  ROOT %t = tuple(%ag, %ar)
}
"""


def test_shape_bytes():
    assert hlo_stats.shape_bytes("bf16[128,1024]{1,0}") == 128 * 1024 * 2
    assert hlo_stats.shape_bytes("f32[64]{0}") == 256
    assert hlo_stats.shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_collective_stats_on_sample():
    st = hlo_stats.collective_stats(SAMPLE)
    assert st.count_by_kind["all-gather"] == 1
    assert st.bytes_by_kind["all-gather"] == 128 * 1024 * 2  # operand p0
    assert st.bytes_by_kind["all-reduce"] == 64 * 4
    assert st.count_by_kind["reduce-scatter"] == 1
    assert st.count_by_kind["collective-permute"] == 1
    assert st.total_bytes > 0


def test_real_compiled_module_psum():
    """An actual jitted psum over 1 device still emits an all-reduce only on
    multi-device; on 1 device we just assert the parser doesn't crash."""
    f = jax.jit(lambda x: x @ x.T)
    compiled = f.lower(jnp.ones((64, 64))).compile()
    st = hlo_stats.collective_stats(compiled.as_text())
    assert st.total_bytes >= 0
    hist = hlo_stats.op_histogram(compiled.as_text())
    assert isinstance(hist, list)


def test_cost_analysis_keys_present():
    f = jax.jit(lambda x: jnp.sum(x @ x.T))
    compiled = f.lower(jnp.ones((128, 128))).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # pre-0.5 jax returns a 1-elem list
        cost = cost[0]
    assert cost.get("flops", 0) > 0
