"""ClientExecutor subsystem: sequential/vmap equivalence, padding masks,
batch materialization, resolution rules and the fl_loop fast paths.

Runs on the TOY mlp task (fast compiles) with hand-built ragged client
sizes so both mask kinds are exercised deterministically: clients smaller
than the batch size (example padding) and clients with fewer steps than
the cohort max (step padding).

The multi-device section at the bottom needs several visible devices; the
CI ``multidevice`` job (and a local repro) provides them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — set BEFORE the
first jax import.  On a single-device run those tests skip, and one
subprocess smoke test keeps the mesh route exercised regardless."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import TOY
from repro.core import algorithms, executor as ex, fl_loop
from repro.data.pipeline import (ClientData, ClientSlabStore, FederatedData,
                                 SLAB_QUANT, batch_iterator, slab_rows)
from repro.data.synthetic import SyntheticTabularTask


RAGGED_SIZES = (20, 45, 64, 100, 130, 150)   # 20 < batch 64 < 150
RAGGED_SIZES_8 = RAGGED_SIZES + (90, 33)     # K=8: divides an 8-device mesh


def _ragged_data(task, sizes):
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(sizes)]
    test_x, test_y = gen.generate(200, seed=999)
    return FederatedData(clients, test_x, test_y,
                         np.zeros((len(sizes), task.num_classes)))


multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny_setup():
    task = dataclasses.replace(TOY, n_clients=len(RAGGED_SIZES),
                               participation=1.0, batch_size=64, rounds=2,
                               local_epochs=2)
    return task, _ragged_data(task, RAGGED_SIZES)


def _max_param_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --- numerical equivalence (the acceptance criterion) ----------------------

@pytest.mark.parametrize("name", ["fedavg", "fedprox", "fedgkd"])
def test_vmap_matches_sequential(tiny_setup, name):
    task, data = tiny_setup
    sizes = {c.n for c in data.clients}
    assert min(sizes) < task.batch_size < max(sizes), \
        "setup must exercise example- AND step-level padding masks"
    out = {}
    for spec in ("sequential", "vmap"):
        h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                  executor=spec)
        out[spec] = h
    assert _max_param_diff(out["sequential"].final_params,
                           out["vmap"].final_params) < 1e-5
    for rs, rv in zip(out["sequential"].records, out["vmap"].records):
        assert abs(rs.mean_local_loss - rv.mean_local_loss) < 1e-5
        assert abs(rs.test_acc - rv.test_acc) < 1e-5


def test_shard_map_executor_matches_sequential(tiny_setup):
    """On one device the executor degrades to the vmap computation; on a
    multi-device host (the CI ``multidevice`` job) this exercises the real
    mesh route with a non-dividing cohort.  Either way: < 1e-5."""
    task, data = tiny_setup
    hs = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor="sequential")
    hm = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor="shard_map")
    assert _max_param_diff(hs.final_params, hm.final_params) < 1e-5


# --- async equivalence (the PR-4 tentpole) ----------------------------------
#
# In the degenerate regime — homogeneous speeds, full buffer B == cohort,
# zero staleness — the buffered-async loop must reproduce the synchronous
# executors: same sampling, same batch draws, same aggregation order.

@pytest.mark.parametrize("name", ["fedavg", "fedprox", "fedgkd",
                                  "fedgkd-vote"])
def test_async_matches_sequential(tiny_setup, name):
    task, data = tiny_setup
    hs = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               rounds=3, executor="sequential")
    ha = fl_loop.run_federated(
        task, algorithms.make(name), data, seed=0, rounds=3,
        executor=ex.AsyncExecutor(staleness="constant"))
    assert _max_param_diff(hs.final_params, ha.final_params) < 1e-5
    for rs, ra in zip(hs.records, ha.records):
        assert abs(rs.mean_local_loss - ra.mean_local_loss) < 1e-5
        assert abs(rs.test_acc - ra.test_acc) < 1e-5
        assert rs.sampled == ra.sampled     # same cohorts, same order
    assert all(r.mean_staleness == 0.0 for r in ha.records)


def test_async_sequential_inner_is_bit_identical(tiny_setup):
    """With the SEQUENTIAL inner executor there is no vmap associativity
    left: the async loop in the degenerate regime is the same computation
    in the same order — bit-identical, not just < 1e-5."""
    task, data = tiny_setup
    hs = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               rounds=2, executor="sequential")
    ha = fl_loop.run_federated(
        task, algorithms.make("fedgkd"), data, seed=0, rounds=2,
        executor=ex.AsyncExecutor(staleness="constant", inner="sequential"))
    assert _max_param_diff(hs.final_params, ha.final_params) == 0.0
    for rs, ra in zip(hs.records, ha.records):
        assert rs.test_acc == ra.test_acc
        assert rs.mean_local_loss == ra.mean_local_loss


@multidevice
@pytest.mark.parametrize("name", ["fedavg", "fedgkd-vote"])
def test_async_shard_map_inner_matches_sequential(tiny_setup, name):
    """The CI multidevice gate: async ready-cohorts on the strict mesh
    route (K=6 padded onto 8 devices, device-resident slabs, sharded
    teacher precompute) still reproduce the sequential reference."""
    task, data = tiny_setup
    hs = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               rounds=3, executor="sequential")
    ha = fl_loop.run_federated(
        task, algorithms.make(name), data, seed=0, rounds=3,
        executor=ex.AsyncExecutor(
            staleness="constant", inner=ex.ShardMapExecutor(strict=True)))
    assert ha.telemetry["inner_route"] == "shard_map"
    assert _max_param_diff(hs.final_params, ha.final_params) < 1e-5
    for rs, ra in zip(hs.records, ha.records):
        assert abs(rs.mean_local_loss - ra.mean_local_loss) < 1e-5


# --- round-level teacher precompute (the PR-2 tentpole) ---------------------

@pytest.mark.parametrize("name", ["fedgkd", "fedgkd-vote", "feddistill+"])
def test_precompute_matches_no_aux_baseline(tiny_setup, name):
    """Sequential/vmap with the precompute_aux stage must reproduce the PR-1
    inline-teacher (no-aux) execution to < 1e-5 on params, losses and acc."""
    task, data = tiny_setup
    algo = algorithms.make(name)
    assert type(algo).precompute_aux is not algorithms.Algorithm.precompute_aux
    base = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                 executor="sequential", precompute=False)
    for spec in ("sequential", "vmap"):
        h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                  executor=spec, precompute=True)
        assert _max_param_diff(base.final_params, h.final_params) < 1e-5, spec
        for rb, rh in zip(base.records, h.records):
            assert abs(rb.mean_local_loss - rh.mean_local_loss) < 1e-5, spec
            assert abs(rb.test_acc - rh.test_acc) < 1e-5, spec


def test_precompute_flag_gates_hook(tiny_setup):
    """precompute=False must force has_precompute off even for KD algos; a
    no-hook algorithm never precomputes."""
    task, _ = tiny_setup
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    model = make_model(task)
    mk = lambda algo, pre: ex.RoundContext(
        algo=algo, model=model, opt=sgd(), lr=0.1, batch_size=64, epochs=1,
        precompute=pre)
    assert mk(algorithms.make("fedgkd"), True).has_precompute
    assert not mk(algorithms.make("fedgkd"), False).has_precompute
    assert not mk(algorithms.make("fedavg"), True).has_precompute


def test_precompute_aux_values_match_inline_teacher(tiny_setup):
    """The gathered aux rows equal the teacher logits the inline loss would
    compute on the same batch."""
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    gkd = algorithms.make("fedgkd", buffer_m=1)
    server = gkd.init_server(params, model, task.num_classes)
    payload = gkd.round_payload(server, jax.random.PRNGKey(1))
    cdata = data.clients[0]
    aux = gkd.precompute_aux(model, payload, jnp.asarray(cdata.x),
                             jnp.asarray(cdata.y),
                             jnp.ones((cdata.n,), jnp.float32))
    rng = np.random.default_rng(5)
    mat = ex.materialize_client(rng, cdata, batch_size=8, epochs=1)
    direct = model.apply(payload["teacher"], jnp.asarray(mat.xs[0]))
    np.testing.assert_allclose(np.asarray(aux["t_logits"][mat.picks[0]]),
                               np.asarray(direct), atol=1e-6)


@pytest.mark.parametrize("name", ["moon", "scaffold", "feddyn",
                                  "feddistill+"])
def test_stateful_algorithms_run_under_vmap(tiny_setup, name):
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                              executor="vmap")
    assert np.isfinite(h.final_acc)
    assert np.isfinite(h.records[-1].mean_local_loss)


# --- masking exactness ------------------------------------------------------

def test_masked_loss_ignores_padded_examples(tiny_setup):
    task, _ = tiny_setup
    from repro.core.modelzoo import make_model
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, task.feat_dim)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3])
    loss = algorithms.make("fedavg").loss_fn(model)
    l_real, _ = loss(params, (), (), x[:2], y[:2], jnp.ones((2,)))
    x_pad = jnp.concatenate([x[:2], jnp.zeros_like(x[:2])])
    l_pad, _ = loss(params, (), (), x_pad, y,
                    jnp.asarray([1.0, 1.0, 0.0, 0.0]))
    np.testing.assert_allclose(float(l_real), float(l_pad), atol=1e-7)


def test_masked_step_is_identity(tiny_setup):
    """A fully padded scan step must leave params AND opt state untouched."""
    task, _ = tiny_setup
    from repro.core import client as client_lib
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(1))
    local = client_lib.make_local_update(
        algorithms.make("fedavg").loss_fn(model), sgd(momentum=0.9))
    xs = jnp.zeros((2, 3, task.feat_dim))
    ys = jnp.zeros((2, 3), jnp.int32)
    ex_mask = jnp.zeros((2, 3), jnp.float32)
    step_mask = jnp.zeros((2,), bool)
    new_params, mloss = jax.jit(local)(params, (), (), xs, ys, ex_mask, (),
                                       step_mask, 0.1)
    assert _max_param_diff(params, new_params) == 0.0
    assert float(mloss) == 0.0


# --- batch materialization --------------------------------------------------

def test_materialize_matches_batch_iterator():
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    data = ClientData(np.arange(22, dtype=np.float32).reshape(22, 1),
                      np.arange(22) % 3)
    mat = ex.materialize_client(rng_a, data, batch_size=8, epochs=2)
    ref = list(batch_iterator(rng_b, data, 8, 2))
    assert mat.xs.shape[0] == len(ref)
    for s, (x, y) in enumerate(ref):
        np.testing.assert_array_equal(mat.xs[s], x)
        np.testing.assert_array_equal(mat.ys[s], y)


def test_materialize_max_batches_rng_consumption():
    """Stopping early must not draw later epochs' permutations (so a given
    seed produces identical batches whether or not max_batches is set)."""
    rng_a = np.random.default_rng(9)
    rng_b = np.random.default_rng(9)
    data = ClientData(np.arange(10, dtype=np.float32).reshape(10, 1),
                      np.arange(10) % 2)
    mat = ex.materialize_client(rng_a, data, batch_size=4, epochs=5,
                                max_batches=2)
    assert mat.xs.shape[0] == 2
    full = ex.materialize_client(rng_b, data, batch_size=4, epochs=1)
    np.testing.assert_array_equal(mat.xs, full.xs[:2])


def test_pad_and_stack_masks():
    mk = lambda s, b: ex.MaterializedClient(
        np.ones((s, b, 2), np.float32), np.ones((s, b), np.int64), s * b,
        np.arange(s * b, dtype=np.int32).reshape(s, b) % max(1, s * b // 2))
    xs, ys, ex_mask, picks, step_mask = ex._pad_and_stack([mk(3, 4), mk(1, 2)])
    assert xs.shape == (2, 3, 4, 2)
    assert picks.shape == (2, 3, 4)
    assert float(ex_mask[0].sum()) == 12.0
    assert float(ex_mask[1].sum()) == 2.0
    assert step_mask.tolist() == [[True, True, True], [True, False, False]]
    # padded pick slots are in-range gathers (row 0) for the masked examples
    assert int(picks[1, 1:].max()) == 0


# --- resolution / fl_loop plumbing -----------------------------------------

def test_get_executor_resolution():
    from repro.core.modelzoo import ModelBundle
    avg = algorithms.make("fedavg")
    assert ex.get_executor("auto", avg, 4).name == "vmap"
    assert ex.get_executor("auto", avg, 1).name == "sequential"
    conv = ModelBundle("resnet8", lambda r: {}, lambda p, x: x,
                       lambda p, x: x, vmap_friendly=False)
    assert ex.get_executor("auto", avg, 4, conv).name == "sequential"
    no_vmap = algorithms.make("fedavg")
    no_vmap.supports_vmap = False
    assert ex.get_executor("auto", no_vmap, 4).name == "sequential"
    inst = ex.SequentialExecutor()
    assert ex.get_executor(inst, avg, 4) is inst
    with pytest.raises(ValueError):
        ex.get_executor("nope", avg, 4)
    # the async executor resolves by name; its READY-COHORT inner executor
    # resolves through the same rules
    a = ex.get_executor("async", avg, 4)
    assert isinstance(a, ex.AsyncExecutor)
    assert "async" in ex.available()
    assert a.resolve_inner(avg, 4).name == "vmap"
    assert a.resolve_inner(avg, 1).name == "sequential"
    with pytest.raises(NotImplementedError):
        a.run_round(None, None, None, [], [], np.random.default_rng(0))
    with pytest.raises(ValueError):
        ex.AsyncExecutor(inner="async")
    with pytest.raises(ValueError):
        ex.AsyncExecutor(staleness="nope")


def test_zero_rounds_fast_path(tiny_setup):
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              rounds=0)
    assert h.records == []
    assert h.local_model_acc == 0.0
    assert h.final_params is not None


def test_evaluate_apply_cache(tiny_setup):
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    fl_loop.evaluate(model, params, data.test_x[:32], data.test_y[:32])
    fn = fl_loop._APPLY_CACHE.get(model.apply)
    assert fn is not None
    model2 = make_model(task)     # same backbone => same cached wrapper
    fl_loop.evaluate(model2, params, data.test_x[:32], data.test_y[:32])
    assert fl_loop._APPLY_CACHE.get(model2.apply) is fn


# --- slab layout / placement store (single device is enough) ----------------

def test_slab_rows_quantization():
    assert slab_rows(1) == SLAB_QUANT
    assert slab_rows(SLAB_QUANT) == SLAB_QUANT
    assert slab_rows(SLAB_QUANT + 1) == 2 * SLAB_QUANT


def test_slab_store_residency_and_counters():
    dev = jax.devices()[0]
    store = ClientSlabStore()
    data = ClientData(np.arange(40, dtype=np.float32).reshape(20, 2),
                      np.arange(20) % 3)
    e1 = store.get(7, data, dev)
    assert store.host_transfers == 1 and store.hits == 0
    assert e1["rows"] == slab_rows(20) and e1["n"] == 20
    assert list(e1["x"].devices()) == [dev]
    np.testing.assert_array_equal(np.asarray(e1["x"])[:20], data.x)
    assert np.asarray(e1["y"])[20:].sum() == 0            # zero padding
    e2 = store.get(7, data, dev)                          # resident => hit
    assert e2 is e1 and store.hits == 1 and store.host_transfers == 1
    store.get(None, data, dev)                            # uncached cid
    assert store.host_transfers == 2 and len(store.slabs) == 1
    bigger = ClientData(np.zeros((21, 2), np.float32), np.zeros(21, np.int64))
    store.get(7, bigger, dev)                             # shard grew
    assert store.host_transfers == 3


def test_materialize_picks_matches_materialize_client():
    rng_a, rng_b = np.random.default_rng(3), np.random.default_rng(3)
    data = ClientData(np.arange(44, dtype=np.float32).reshape(22, 2),
                      np.arange(22) % 3)
    picks = ex.materialize_picks(rng_a, data, batch_size=8, epochs=2)
    mat = ex.materialize_client(rng_b, data, batch_size=8, epochs=2)
    np.testing.assert_array_equal(picks, mat.picks)
    np.testing.assert_array_equal(data.x[picks], mat.xs)


def test_pad_and_stack_picks_phantom_clients():
    picks = [np.arange(12, dtype=np.int32).reshape(3, 4),
             np.arange(2, dtype=np.int32).reshape(1, 2)]
    p, ex_mask, step_mask = ex._pad_and_stack_picks(picks, k_pad=4)
    assert p.shape == (4, 3, 4)
    assert float(ex_mask[0].sum()) == 12.0
    assert float(ex_mask[1].sum()) == 2.0
    # phantom clients: every mask zero, every pick an in-range row-0 gather
    assert float(ex_mask[2:].sum()) == 0.0
    assert not step_mask[2:].any()
    assert int(p[2:].max()) == 0


def test_pad_clients_axis():
    tree = {"w": jnp.ones((3, 2)), "b": jnp.ones((3,))}
    out = ex._pad_clients_axis(tree, 5)
    assert out["w"].shape == (5, 2) and out["b"].shape == (5,)
    assert float(out["w"][3:].sum()) == 0.0
    assert ex._pad_clients_axis((), 5) == ()


# --- shard_map route selection / strict mode --------------------------------

def test_shard_map_strict_raises_on_single_device(tiny_setup):
    if len(jax.devices()) != 1:
        pytest.skip("fallback only exists on a single-device host")
    task, data = tiny_setup
    with pytest.raises(RuntimeError, match="strict"):
        fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              executor=ex.ShardMapExecutor(strict=True))


def test_route_telemetry_records_what_ran(tiny_setup):
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    model = make_model(task)
    mk = lambda: ex.RoundContext(algo=algorithms.make("fedavg"), model=model,
                                 opt=sgd(), lr=0.1, batch_size=64, epochs=1)
    gp = model.init(jax.random.PRNGKey(0))
    states = [() for _ in data.clients]
    for exec_, want in ((ex.SequentialExecutor(), "sequential"),
                        (ex.VmapExecutor(), "vmap")):
        ctx = mk()
        exec_.run_round(ctx, gp, (), states, data.clients,
                        np.random.default_rng(0))
        assert ctx.telemetry["route"] == want
    ctx = mk()
    ex.ShardMapExecutor().run_round(ctx, gp, (), states, data.clients,
                                    np.random.default_rng(0))
    want = "vmap-fallback" if len(jax.devices()) == 1 else "shard_map"
    assert ctx.telemetry["route"] == want
    assert ctx.telemetry["n_devices"] == len(jax.devices())


# --- the real multi-device path (CI `multidevice` job) ----------------------

@pytest.fixture(scope="module", params=[RAGGED_SIZES, RAGGED_SIZES_8],
                ids=["K6", "K8"])
def cohort_setup(request):
    sizes = request.param
    task = dataclasses.replace(TOY, n_clients=len(sizes), participation=1.0,
                               batch_size=64, rounds=2, local_epochs=2)
    return task, _ragged_data(task, sizes)


@multidevice
@pytest.mark.parametrize("name", ["fedavg", "fedprox", "fedgkd",
                                  "fedgkd-vote"])
def test_shard_map_cohorts_match_sequential(cohort_setup, name):
    """The acceptance criterion: K=6 (non-dividing, padded with phantoms)
    AND K=8 (dividing) ragged cohorts on an 8-device host, strict mode (no
    fallback permitted), < 1e-5 vs the sequential reference."""
    task, data = cohort_setup
    hs = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               executor="sequential")
    hm = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               executor=ex.ShardMapExecutor(strict=True))
    assert _max_param_diff(hs.final_params, hm.final_params) < 1e-5
    for rs, rm in zip(hs.records, hm.records):
        assert abs(rs.mean_local_loss - rm.mean_local_loss) < 1e-5
        assert abs(rs.test_acc - rm.test_acc) < 1e-5


@multidevice
def test_shard_map_mixed_member_phantom_shard():
    """K=13 (prime) on any 2..8-device mesh: some device's shard stack
    holds BOTH real clients and phantom padding in the same round — the
    members/phantom split boundary inside ``_resident_cohort``."""
    sizes = RAGGED_SIZES + (90, 33, 70, 25, 140, 55, 80)
    k, ndev = len(sizes), len(jax.devices())
    assert k == 13
    if k % ndev == 0:
        pytest.skip("needs a device count that does not divide K=13")
    g = -(-k // ndev)
    assert any(0 < k - d * g < g for d in range(ndev)), \
        "setup must yield a device owning real AND phantom clients"
    task = dataclasses.replace(TOY, n_clients=k, participation=1.0,
                               batch_size=64, rounds=1, local_epochs=1)
    data = _ragged_data(task, sizes)
    hs = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor="sequential")
    hm = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor=ex.ShardMapExecutor(strict=True))
    assert _max_param_diff(hs.final_params, hm.final_params) < 1e-5


@multidevice
def test_shard_map_parts_cache_survives_cohort_churn(tiny_setup):
    """Partial participation: rotating cohorts must NOT flush the teacher
    part cache — a version is recomputed only when some sampled client has
    never seen it, so full-overlap rotations reassemble instead."""
    task, data = tiny_setup
    from repro.optim import sgd
    m_teachers = 3
    algo, model, gp, payload0, _ = _vote_ctx_and_payloads(task, m_teachers)
    ctx = ex.RoundContext(algo=algo, model=model, opt=sgd(), lr=0.05,
                          batch_size=64, epochs=1)
    exec_ = ex.ShardMapExecutor(strict=True)
    rng = np.random.default_rng(0)
    k = len(data.clients)
    cohorts = [list(range(k)),                 # cold: M forwards
               list(range(k - 1, -1, -1)),     # same clients, new order
               list(range(k))]                 # back again
    for cohort in cohorts:
        exec_.run_round(ctx, gp, payload0, [() for _ in cohort],
                        [data.clients[c] for c in cohort], rng,
                        client_ids=cohort)
    assert ctx.telemetry["parts_computed"] == m_teachers, \
        "cohort churn with full overlap must reassemble, not recompute"


def test_slab_store_lru_eviction():
    dev = jax.devices()[0]
    from repro.data.pipeline import ClientSlabStore as Store
    store = Store(max_resident=2)
    mk = lambda n: ClientData(np.zeros((n, 2), np.float32),
                              np.zeros(n, np.int64))
    store.get(0, mk(8), dev)
    store.get(1, mk(8), dev)
    store.get(0, mk(8), dev)          # refresh 0 -> 1 is now LRU
    store.get(2, mk(8), dev)          # evicts 1
    assert set(store.slabs) == {0, 2}
    assert store.evictions == 1
    store.get(1, mk(8), dev)          # re-upload after eviction
    assert store.host_transfers == 4


@multidevice
def test_shard_map_strict_never_falls_back(tiny_setup):
    """Regression for the PR-2 silent-fallback footgun: K=6 on a
    multi-device host pads to the mesh and runs shard_map, never vmap."""
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    algo = algorithms.make("fedavg")
    model = make_model(task)
    ctx = ex.RoundContext(algo=algo, model=model, opt=sgd(), lr=0.1,
                          batch_size=64, epochs=1)
    gp = model.init(jax.random.PRNGKey(0))
    ex.ShardMapExecutor(strict=True).run_round(
        ctx, gp, (), [() for _ in data.clients], data.clients,
        np.random.default_rng(0), client_ids=list(range(len(data.clients))))
    ndev = len(jax.devices())
    assert ctx.telemetry["route"] == "shard_map"
    assert ctx.telemetry["cohort"] == len(data.clients)
    assert ctx.telemetry["padded_to"] % ndev == 0
    assert ctx.telemetry["padded_to"] >= len(data.clients)


def _vote_ctx_and_payloads(task, n_teachers=3):
    """A FedGKD-VOTE round context plus payloads before/after one teacher
    rotation (buffer filled so the ensemble is real)."""
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    algo = algorithms.make("fedgkd-vote", buffer_m=n_teachers)
    model = make_model(task)
    gp = model.init(jax.random.PRNGKey(0))
    server = algo.init_server(gp, model, task.num_classes)
    for m in range(n_teachers - 1):
        server["buffer"].push(jax.tree_util.tree_map(
            lambda p: p * (1.0 + 0.01 * (m + 1)), gp))
    server["val_losses"] = [0.1 * (m + 1) for m in range(n_teachers)]
    p0 = algo.round_payload(server, jax.random.PRNGKey(1))
    server["buffer"].push(jax.tree_util.tree_map(lambda p: p * 1.05, gp))
    p1 = algo.round_payload(server, jax.random.PRNGKey(2))
    return algo, model, gp, p0, p1


@multidevice
def test_shard_map_slab_reuse_and_part_invalidation(tiny_setup):
    """Device-resident reuse: a client sampled in consecutive rounds does
    NOT re-upload its shard; a ModelBuffer version bump invalidates exactly
    the one stale teacher part."""
    task, data = tiny_setup
    from repro.optim import sgd
    m_teachers = 3
    algo, model, gp, payload0, payload1 = _vote_ctx_and_payloads(
        task, m_teachers)
    ctx = ex.RoundContext(algo=algo, model=model, opt=sgd(), lr=0.05,
                          batch_size=64, epochs=1)
    exec_ = ex.ShardMapExecutor(strict=True)
    rng = np.random.default_rng(0)
    states = [() for _ in data.clients]
    cids = list(range(len(data.clients)))
    k = len(data.clients)

    exec_.run_round(ctx, gp, payload0, states, data.clients, rng,
                    client_ids=cids)
    t1 = dict(ctx.telemetry)
    assert t1["placement"]["host_transfers"] == k     # one upload per client
    assert t1["parts_computed"] == m_teachers         # cold cache: M forwards

    exec_.run_round(ctx, gp, payload0, states, data.clients, rng,
                    client_ids=cids)
    t2 = dict(ctx.telemetry)
    assert t2["placement"]["host_transfers"] == k, "shards must stay resident"
    assert t2["parts_computed"] == m_teachers, "all teacher parts cached"

    exec_.run_round(ctx, gp, payload1, states, data.clients, rng,
                    client_ids=cids)                  # ONE teacher rotated
    t3 = dict(ctx.telemetry)
    assert t3["parts_computed"] == m_teachers + 1, \
        "version bump must invalidate exactly the one stale part"
    assert t3["placement"]["host_transfers"] == k

    # placement introspection: every slab pinned to exactly its slot device
    for entry in ctx.placement.slabs.values():
        assert list(entry["x"].devices()) == [entry["device"]]


@multidevice
def test_shard_map_precompute_matches_no_aux_baseline(tiny_setup):
    """The mesh-routed teacher precompute (fedgkd direct + fedgkd-vote
    parts path) must reproduce the inline no-aux loss to < 1e-5."""
    task, data = tiny_setup
    for name in ("fedgkd", "fedgkd-vote"):
        base = fl_loop.run_federated(task, algorithms.make(name), data,
                                     seed=0, executor="sequential",
                                     precompute=False)
        h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                  executor=ex.ShardMapExecutor(strict=True),
                                  precompute=True)
        assert _max_param_diff(base.final_params, h.final_params) < 1e-5, name


# --- subprocess smoke: keeps the mesh route alive on single-device boxes ----

_SMOKE_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.paper import TOY
    from repro.core import algorithms, executor as ex, fl_loop
    from repro.data.pipeline import ClientData, FederatedData
    from repro.data.synthetic import SyntheticTabularTask

    sizes = (20, 45, 64, 100, 130, 150)
    task = dataclasses.replace(TOY, n_clients=6, participation=1.0,
                               batch_size=64, rounds=1, local_epochs=1)
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(sizes)]
    tx, ty = gen.generate(100, seed=999)
    data = FederatedData(clients, tx, ty, np.zeros((6, task.num_classes)))
    hs = fl_loop.run_federated(task, algorithms.make("fedgkd"), data,
                               seed=0, executor="sequential")
    hm = fl_loop.run_federated(task, algorithms.make("fedgkd"), data,
                               seed=0,
                               executor=ex.ShardMapExecutor(strict=True))
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(hs.final_params),
        jax.tree_util.tree_leaves(hm.final_params)))
    assert len(jax.devices()) == 8, len(jax.devices())
    assert d < 1e-5, d
    print("SMOKE_OK", d)
""")


def test_shard_map_multidevice_subprocess_smoke():
    """Guard for single-device boxes: the strict mesh route (K=6 on 8
    forced host devices) must run without fallback and match sequential
    even when the main pytest process has one device.  The CI multidevice
    job covers the full matrix in-process, so there this subprocess rerun
    would only duplicate coverage — skip it."""
    if len(jax.devices()) >= 2:
        pytest.skip("in-process multidevice tests already cover the route")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SMOKE_SNIPPET],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SMOKE_OK" in out.stdout


# --- the client-batched conv route (resnet8, kernels/grouped_conv) ----------
#
# The paper's CV backbone: stacked per-client conv weights route through
# kernels.grouped_conv instead of vmapping the round body.  Tiny shapes
# (16x16 images, width 8) keep compiles CI-sized; lr is small so fp32
# reassociation across the grouped rewrite stays far inside 1e-5.

RESNET_SIZES = (5, 9, 12, 20, 8, 16)        # ragged, 6 clients (mesh-padded)


@pytest.fixture(scope="module")
def resnet_setup():
    from repro.configs.paper import CIFAR10
    from repro.data.synthetic import SyntheticImageTask
    task = dataclasses.replace(CIFAR10, n_clients=len(RESNET_SIZES),
                               participation=1.0, batch_size=8, rounds=2,
                               local_epochs=1, image_hw=16, lr=0.01)
    gen = SyntheticImageTask(task.num_classes, hw=task.image_hw, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(RESNET_SIZES)]
    tx, ty = gen.generate(64, seed=999)
    return task, FederatedData(clients, tx, ty,
                               np.zeros((len(RESNET_SIZES),
                                         task.num_classes)))


@pytest.mark.parametrize("name", ["fedavg", "fedgkd"])
def test_resnet8_vmap_matches_sequential(resnet_setup, name):
    """seq vs vmap on the conv backbone: the vmap executor must pick the
    client-batched body (telemetry) and reproduce the reference < 1e-5."""
    task, data = resnet_setup
    hs = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               width=8, executor="sequential")
    hv = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                               width=8, executor="vmap")
    assert hv.telemetry["round_body"] == "client_batched"
    assert _max_param_diff(hs.final_params, hv.final_params) < 1e-5
    for rs, rv in zip(hs.records, hv.records):
        assert abs(rs.mean_local_loss - rv.mean_local_loss) < 1e-5
        assert abs(rs.test_acc - rv.test_acc) < 1e-5


def test_resnet8_naive_body_still_available(resnet_setup):
    """client_batched=False forces the historical vmapped-conv body (the
    conv benchmark's baseline) and still matches the batched body."""
    task, data = resnet_setup
    hn = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               width=8, executor="vmap",
                               client_batched=False)
    hb = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               width=8, executor="vmap")
    assert hn.telemetry["round_body"] == "vmap"
    assert hb.telemetry["round_body"] == "client_batched"
    assert _max_param_diff(hn.final_params, hb.final_params) < 1e-5


def test_resnet8_async_inner_matches_sequential(resnet_setup):
    """Async degenerate regime with the vmap (client-batched) inner."""
    task, data = resnet_setup
    hs = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               width=8, rounds=2, executor="sequential")
    ha = fl_loop.run_federated(
        task, algorithms.make("fedgkd"), data, seed=0, width=8, rounds=2,
        executor=ex.AsyncExecutor(staleness="constant", inner="vmap"))
    assert _max_param_diff(hs.final_params, ha.final_params) < 1e-5


def test_resnet8_auto_resolution():
    """'auto' now selects the batched route for conv backbones (closing
    the ROADMAP caveat) — but only when the algorithm has a stacked loss."""
    from repro.configs.paper import CIFAR10
    from repro.core.modelzoo import make_model
    model = make_model(CIFAR10)
    assert model.client_batched and not model.vmap_friendly
    assert ex.get_executor("auto", algorithms.make("fedavg"), 4,
                           model).name == "vmap"
    # moon overrides loss_fn without a stacked form -> sequential
    moon_model = make_model(CIFAR10, projection_head=True)
    assert ex.get_executor("auto", algorithms.make("moon"), 4,
                           moon_model).name == "sequential"


def test_round_context_client_batched_flag():
    from repro.configs.paper import CIFAR10, TOY
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    mk = lambda task, algo, cb: ex.RoundContext(
        algo=algorithms.make(algo), model=make_model(task), opt=sgd(),
        lr=0.1, batch_size=8, epochs=1, client_batched=cb)
    assert mk(CIFAR10, "fedavg", "auto").batched_local_update is not None
    assert mk(CIFAR10, "fedavg", False).batched_local_update is None
    assert mk(TOY, "fedavg", "auto").batched_local_update is None  # mlp
    assert mk(CIFAR10, "moon", "auto").batched_local_update is None
    with pytest.raises(ValueError, match="client_batched=True"):
        mk(TOY, "fedavg", True)


@multidevice
def test_resnet8_shard_map_strict_matches_sequential(resnet_setup):
    """K=6 ragged resnet8 cohort on the 8-device mesh, strict (no
    fallback): each shard trains its resident clients through the
    client-batched grouped-conv body; < 1e-5 vs sequential."""
    task, data = resnet_setup
    hs = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               width=8, executor="sequential")
    hm = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               width=8,
                               executor=ex.ShardMapExecutor(strict=True))
    assert hm.telemetry["route"] == "shard_map"
    assert hm.telemetry["round_body"] == "client_batched"
    assert _max_param_diff(hs.final_params, hm.final_params) < 1e-5


def test_resnet8_adam_matches_sequential(resnet_setup):
    """Adam's scalar step-count state leaf must stay per-client on the
    batched route (the opt init/update are vmapped) — regression for the
    keep-mask breaking on scalar optimizer state."""
    task, data = resnet_setup
    task = dataclasses.replace(task, optimizer="adam", lr=1e-3,
                               weight_decay=0.0)
    hs = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               width=8, rounds=1, executor="sequential")
    hv = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                               width=8, rounds=1, executor="vmap")
    assert hv.telemetry["round_body"] == "client_batched"
    assert _max_param_diff(hs.final_params, hv.final_params) < 1e-5


def test_batched_loss_guard_on_loss_override():
    """A subclass overriding loss_fn WITHOUT a stacked form must not
    inherit the parent's batched loss (it would silently train the wrong
    objective on the batched route)."""
    from repro.configs.paper import CIFAR10
    from repro.core.modelzoo import make_model
    model = make_model(CIFAR10)

    class CustomGKD(algorithms.FedGKD):
        def loss_fn(self, m):
            return super().loss_fn(m)

    class CustomProx(algorithms.FedProx):
        def loss_fn(self, m):
            return super().loss_fn(m)

    assert CustomGKD().batched_loss_fn(model) is None
    assert CustomProx().batched_loss_fn(model) is None
    # inheriting BOTH unchanged keeps the batched form (fedgkd+)
    ph = make_model(CIFAR10, projection_head=True)
    assert algorithms.FedGKDPlus().batched_loss_fn(ph) is not None
