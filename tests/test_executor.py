"""ClientExecutor subsystem: sequential/vmap equivalence, padding masks,
batch materialization, resolution rules and the fl_loop fast paths.

Runs on the TOY mlp task (fast compiles) with hand-built ragged client
sizes so both mask kinds are exercised deterministically: clients smaller
than the batch size (example padding) and clients with fewer steps than
the cohort max (step padding)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import TOY
from repro.core import algorithms, executor as ex, fl_loop
from repro.data.pipeline import ClientData, FederatedData, batch_iterator
from repro.data.synthetic import SyntheticTabularTask


RAGGED_SIZES = (20, 45, 64, 100, 130, 150)   # 20 < batch 64 < 150


@pytest.fixture(scope="module")
def tiny_setup():
    task = dataclasses.replace(TOY, n_clients=len(RAGGED_SIZES),
                               participation=1.0, batch_size=64, rounds=2,
                               local_epochs=2)
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(RAGGED_SIZES)]
    test_x, test_y = gen.generate(200, seed=999)
    data = FederatedData(clients, test_x, test_y,
                         np.zeros((task.n_clients, task.num_classes)))
    return task, data


def _max_param_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --- numerical equivalence (the acceptance criterion) ----------------------

@pytest.mark.parametrize("name", ["fedavg", "fedprox", "fedgkd"])
def test_vmap_matches_sequential(tiny_setup, name):
    task, data = tiny_setup
    sizes = {c.n for c in data.clients}
    assert min(sizes) < task.batch_size < max(sizes), \
        "setup must exercise example- AND step-level padding masks"
    out = {}
    for spec in ("sequential", "vmap"):
        h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                  executor=spec)
        out[spec] = h
    assert _max_param_diff(out["sequential"].final_params,
                           out["vmap"].final_params) < 1e-5
    for rs, rv in zip(out["sequential"].records, out["vmap"].records):
        assert abs(rs.mean_local_loss - rv.mean_local_loss) < 1e-5
        assert abs(rs.test_acc - rv.test_acc) < 1e-5


def test_shard_map_executor_matches_sequential(tiny_setup):
    """Single device => degrades to the vmap computation; still must agree."""
    task, data = tiny_setup
    hs = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor="sequential")
    hm = fl_loop.run_federated(task, algorithms.make("fedgkd"), data, seed=0,
                               executor="shard_map")
    assert _max_param_diff(hs.final_params, hm.final_params) < 1e-5


# --- round-level teacher precompute (the PR-2 tentpole) ---------------------

@pytest.mark.parametrize("name", ["fedgkd", "fedgkd-vote", "feddistill+"])
def test_precompute_matches_no_aux_baseline(tiny_setup, name):
    """Sequential/vmap with the precompute_aux stage must reproduce the PR-1
    inline-teacher (no-aux) execution to < 1e-5 on params, losses and acc."""
    task, data = tiny_setup
    algo = algorithms.make(name)
    assert type(algo).precompute_aux is not algorithms.Algorithm.precompute_aux
    base = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                 executor="sequential", precompute=False)
    for spec in ("sequential", "vmap"):
        h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                                  executor=spec, precompute=True)
        assert _max_param_diff(base.final_params, h.final_params) < 1e-5, spec
        for rb, rh in zip(base.records, h.records):
            assert abs(rb.mean_local_loss - rh.mean_local_loss) < 1e-5, spec
            assert abs(rb.test_acc - rh.test_acc) < 1e-5, spec


def test_precompute_flag_gates_hook(tiny_setup):
    """precompute=False must force has_precompute off even for KD algos; a
    no-hook algorithm never precomputes."""
    task, _ = tiny_setup
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    model = make_model(task)
    mk = lambda algo, pre: ex.RoundContext(
        algo=algo, model=model, opt=sgd(), lr=0.1, batch_size=64, epochs=1,
        precompute=pre)
    assert mk(algorithms.make("fedgkd"), True).has_precompute
    assert not mk(algorithms.make("fedgkd"), False).has_precompute
    assert not mk(algorithms.make("fedavg"), True).has_precompute


def test_precompute_aux_values_match_inline_teacher(tiny_setup):
    """The gathered aux rows equal the teacher logits the inline loss would
    compute on the same batch."""
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    gkd = algorithms.make("fedgkd", buffer_m=1)
    server = gkd.init_server(params, model, task.num_classes)
    payload = gkd.round_payload(server, jax.random.PRNGKey(1))
    cdata = data.clients[0]
    aux = gkd.precompute_aux(model, payload, jnp.asarray(cdata.x),
                             jnp.asarray(cdata.y),
                             jnp.ones((cdata.n,), jnp.float32))
    rng = np.random.default_rng(5)
    mat = ex.materialize_client(rng, cdata, batch_size=8, epochs=1)
    direct = model.apply(payload["teacher"], jnp.asarray(mat.xs[0]))
    np.testing.assert_allclose(np.asarray(aux["t_logits"][mat.picks[0]]),
                               np.asarray(direct), atol=1e-6)


@pytest.mark.parametrize("name", ["moon", "scaffold", "feddyn",
                                  "feddistill+"])
def test_stateful_algorithms_run_under_vmap(tiny_setup, name):
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make(name), data, seed=0,
                              executor="vmap")
    assert np.isfinite(h.final_acc)
    assert np.isfinite(h.records[-1].mean_local_loss)


# --- masking exactness ------------------------------------------------------

def test_masked_loss_ignores_padded_examples(tiny_setup):
    task, _ = tiny_setup
    from repro.core.modelzoo import make_model
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, task.feat_dim)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3])
    loss = algorithms.make("fedavg").loss_fn(model)
    l_real, _ = loss(params, (), (), x[:2], y[:2], jnp.ones((2,)))
    x_pad = jnp.concatenate([x[:2], jnp.zeros_like(x[:2])])
    l_pad, _ = loss(params, (), (), x_pad, y,
                    jnp.asarray([1.0, 1.0, 0.0, 0.0]))
    np.testing.assert_allclose(float(l_real), float(l_pad), atol=1e-7)


def test_masked_step_is_identity(tiny_setup):
    """A fully padded scan step must leave params AND opt state untouched."""
    task, _ = tiny_setup
    from repro.core import client as client_lib
    from repro.core.modelzoo import make_model
    from repro.optim import sgd
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(1))
    local = client_lib.make_local_update(
        algorithms.make("fedavg").loss_fn(model), sgd(momentum=0.9))
    xs = jnp.zeros((2, 3, task.feat_dim))
    ys = jnp.zeros((2, 3), jnp.int32)
    ex_mask = jnp.zeros((2, 3), jnp.float32)
    step_mask = jnp.zeros((2,), bool)
    new_params, mloss = jax.jit(local)(params, (), (), xs, ys, ex_mask, (),
                                       step_mask, 0.1)
    assert _max_param_diff(params, new_params) == 0.0
    assert float(mloss) == 0.0


# --- batch materialization --------------------------------------------------

def test_materialize_matches_batch_iterator():
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    data = ClientData(np.arange(22, dtype=np.float32).reshape(22, 1),
                      np.arange(22) % 3)
    mat = ex.materialize_client(rng_a, data, batch_size=8, epochs=2)
    ref = list(batch_iterator(rng_b, data, 8, 2))
    assert mat.xs.shape[0] == len(ref)
    for s, (x, y) in enumerate(ref):
        np.testing.assert_array_equal(mat.xs[s], x)
        np.testing.assert_array_equal(mat.ys[s], y)


def test_materialize_max_batches_rng_consumption():
    """Stopping early must not draw later epochs' permutations (so a given
    seed produces identical batches whether or not max_batches is set)."""
    rng_a = np.random.default_rng(9)
    rng_b = np.random.default_rng(9)
    data = ClientData(np.arange(10, dtype=np.float32).reshape(10, 1),
                      np.arange(10) % 2)
    mat = ex.materialize_client(rng_a, data, batch_size=4, epochs=5,
                                max_batches=2)
    assert mat.xs.shape[0] == 2
    full = ex.materialize_client(rng_b, data, batch_size=4, epochs=1)
    np.testing.assert_array_equal(mat.xs, full.xs[:2])


def test_pad_and_stack_masks():
    mk = lambda s, b: ex.MaterializedClient(
        np.ones((s, b, 2), np.float32), np.ones((s, b), np.int64), s * b,
        np.arange(s * b, dtype=np.int32).reshape(s, b) % max(1, s * b // 2))
    xs, ys, ex_mask, picks, step_mask = ex._pad_and_stack([mk(3, 4), mk(1, 2)])
    assert xs.shape == (2, 3, 4, 2)
    assert picks.shape == (2, 3, 4)
    assert float(ex_mask[0].sum()) == 12.0
    assert float(ex_mask[1].sum()) == 2.0
    assert step_mask.tolist() == [[True, True, True], [True, False, False]]
    # padded pick slots are in-range gathers (row 0) for the masked examples
    assert int(picks[1, 1:].max()) == 0


# --- resolution / fl_loop plumbing -----------------------------------------

def test_get_executor_resolution():
    from repro.core.modelzoo import ModelBundle
    avg = algorithms.make("fedavg")
    assert ex.get_executor("auto", avg, 4).name == "vmap"
    assert ex.get_executor("auto", avg, 1).name == "sequential"
    conv = ModelBundle("resnet8", lambda r: {}, lambda p, x: x,
                       lambda p, x: x, vmap_friendly=False)
    assert ex.get_executor("auto", avg, 4, conv).name == "sequential"
    no_vmap = algorithms.make("fedavg")
    no_vmap.supports_vmap = False
    assert ex.get_executor("auto", no_vmap, 4).name == "sequential"
    inst = ex.SequentialExecutor()
    assert ex.get_executor(inst, avg, 4) is inst
    with pytest.raises(ValueError):
        ex.get_executor("nope", avg, 4)


def test_zero_rounds_fast_path(tiny_setup):
    task, data = tiny_setup
    h = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              rounds=0)
    assert h.records == []
    assert h.local_model_acc == 0.0
    assert h.final_params is not None


def test_evaluate_apply_cache(tiny_setup):
    task, data = tiny_setup
    from repro.core.modelzoo import make_model
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    fl_loop.evaluate(model, params, data.test_x[:32], data.test_y[:32])
    fn = fl_loop._APPLY_CACHE.get(model.apply)
    assert fn is not None
    model2 = make_model(task)     # same backbone => same cached wrapper
    fl_loop.evaluate(model2, params, data.test_x[:32], data.test_y[:32])
    assert fl_loop._APPLY_CACHE.get(model2.apply) is fn
