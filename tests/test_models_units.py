"""Model-substrate unit + property tests: norms, RoPE, MoE, SSD, attention."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, layers, moe as moe_lib, ssm
from proptest import sweep


# --- layers ------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
    p = layers.rmsnorm_init(64)
    y = layers.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_groupnorm_normalizes_groups():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 32)) * 5 + 3
    p = layers.groupnorm_init(32)
    y = layers.groupnorm(p, x, num_groups=2)
    yg = np.asarray(y).reshape(2, 8, 8, 2, 16)
    np.testing.assert_allclose(yg.mean((1, 2, 4)), 0.0, atol=1e-4)
    np.testing.assert_allclose(yg.std((1, 2, 4)), 1.0, atol=1e-3)


@sweep(n=8)
def test_rope_preserves_norm_and_relativity(rng):
    """RoPE is orthogonal (norm-preserving) and relative: q·k depends only
    on position difference."""
    d = int(rng.choice([16, 32, 64]))
    x = jnp.asarray(rng.standard_normal((1, 6, 2, d)), jnp.float32)
    pos = jnp.arange(6)[None, :]
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    def dot_at(pq, pk):
        qq = layers.apply_rope(q, jnp.asarray([[pq]]))
        kk = layers.apply_rope(k, jnp.asarray([[pk]]))
        return float(jnp.sum(qq * kk))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-3, atol=1e-4)


# --- attention ----------------------------------------------------------------

def test_causal_mask_window():
    m = attention.causal_mask(4, 4, window=2)
    want = np.array([[1, 0, 0, 0], [1, 1, 0, 0], [0, 1, 1, 0], [0, 0, 1, 1]],
                    bool)
    np.testing.assert_array_equal(np.asarray(m), want)


def test_gqa_equals_mha_when_repeated():
    """GQA with kv heads repeated == MHA with duplicated kv heads."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 8, 4, 16))
    k = jax.random.normal(ks[1], (1, 8, 2, 16))
    v = jax.random.normal(ks[2], (1, 8, 2, 16))
    mask = attention.causal_mask(8, 8)
    out_gqa = attention.dot_product_attention(q, k, v, mask)
    k_full = jnp.repeat(k, 2, axis=2)
    v_full = jnp.repeat(v, 2, axis=2)
    out_mha = attention.dot_product_attention(q, k_full, v_full, mask)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               atol=1e-5)


def test_mla_decode_matches_prefill():
    cfg = attention.MLAConfig(d_model=64, n_heads=4, q_lora_rank=32,
                              kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                              v_head_dim=16)
    params = attention.mla_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 64))
    pos = jnp.arange(6)[None, :]
    full = attention.mla_attention(params, x, cfg, pos)
    cache = attention.mla_cache_init(1, 8, cfg, jnp.float32)
    outs = []
    for i in range(6):
        y, cache = attention.mla_decode_step(params, x[:, i:i + 1], cache, cfg)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


# --- MoE ------------------------------------------------------------------------

def _moe_cfg(**kw):
    base = dict(d_model=32, d_ff=64, n_experts=4, top_k=2, group_size=64,
                capacity_factor=2.0)
    base.update(kw)
    return moe_lib.MoEConfig(**base)


def test_moe_lossless_equals_dense_mixture():
    """With capacity >= tokens, MoE out == explicit top-k expert mixture."""
    cfg = _moe_cfg(capacity_factor=4.0)  # cap = 16·2/4·4 = 32 ≥ tokens
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    out, aux = moe_lib.moe_apply(params, x, cfg)
    xf = x.reshape(16, 32)
    gates, idx, _ = moe_lib.router_probs(params, xf, cfg)
    want = np.zeros((16, 32), np.float32)
    for t in range(16):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(xf[t] @ params["gate"][e]) * (xf[t] @ params["up"][e])
            want[t] += float(gates[t, j]) * np.asarray(h @ params["down"][e])
    np.testing.assert_allclose(np.asarray(out[0]), want, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(capacity_factor=0.1)  # tiny capacity → heavy dropping
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    out, _ = moe_lib.moe_apply(params, x, cfg)
    # most tokens dropped ⇒ many all-zero routed outputs
    zero_rows = np.mean(np.all(np.abs(np.asarray(out[0])) < 1e-7, axis=-1))
    assert zero_rows > 0.3


def test_moe_group_scan_equivalence():
    """Grouped (scanned) dispatch == single-group dispatch when capacity
    scales with group count."""
    params = moe_lib.moe_init(jax.random.PRNGKey(0), _moe_cfg())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    big = _moe_cfg(group_size=64, capacity_factor=16.0)
    small = _moe_cfg(group_size=16, capacity_factor=16.0)
    o1, _ = moe_lib.moe_apply(params, x, big)
    o2, _ = moe_lib.moe_apply(params, x, small)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_moe_shared_expert_added():
    cfg = _moe_cfg(n_shared_experts=1, shared_d_ff=64)
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = moe_lib.moe_apply(params, x, cfg)
    from repro.models.layers import swiglu
    no_shared, _ = moe_lib.moe_apply(params, x, cfg._replace(n_shared_experts=0))
    shared = swiglu(params["shared"], x.reshape(8, 32)).reshape(1, 8, 32)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(no_shared + shared), atol=1e-5)


def test_sigmoid_router_gates_normalized():
    cfg = _moe_cfg(router_type="sigmoid")
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
    gates, idx, probs = moe_lib.router_probs(params, x, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)


# --- SSM ------------------------------------------------------------------------

def test_ssd_chunk_invariance():
    """Different chunk sizes must give identical results."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (1, 64, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)))
    B = jax.random.normal(ks[3], (1, 64, 1, 8))
    C = jax.random.normal(ks[4], (1, 64, 1, 8))
    y8, s8 = ssm.ssd_chunked(x, dt, A, B, C, chunk=8)
    y32, s32 = ssm.ssd_chunked(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s32), atol=1e-4)


def test_mamba_block_decode_matches_forward():
    cfg = ssm.SSMConfig(d_model=32, d_state=8, head_dim=8, expand=2,
                        d_conv=4, chunk=8)
    params = ssm.mamba2_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
    full, _ = ssm.mamba2_forward(params, x, cfg)
    cache = ssm.ssm_cache_init(1, cfg)
    outs = []
    for i in range(12):
        y, cache = ssm.mamba2_decode_step(params, x[:, i:i + 1], cache, cfg)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-3, atol=1e-4)
