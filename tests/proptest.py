"""Minimal property-test harness (hypothesis is unavailable offline).

``sweep(n)(fn)`` runs ``fn(rng)`` for n seeded numpy Generators; failures
report the seed so the case is reproducible.  Generators below mirror the
hypothesis strategies we'd otherwise use.
"""
from __future__ import annotations

import functools

import numpy as np


def sweep(n: int = 20, base_seed: int = 0):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            for i in range(n):
                seed = base_seed + i
                rng = np.random.default_rng(seed)
                try:
                    fn(rng, *a, **kw)
                except AssertionError as e:
                    raise AssertionError(f"[proptest seed={seed}] {e}") from e
        # hide the wrapped signature so pytest doesn't treat `rng` as a fixture
        del wrapper.__wrapped__
        return wrapper
    return deco


def rand_shape(rng, ndim_lo=1, ndim_hi=3, dim_lo=1, dim_hi=64):
    nd = int(rng.integers(ndim_lo, ndim_hi + 1))
    return tuple(int(rng.integers(dim_lo, dim_hi + 1)) for _ in range(nd))


def rand_logits(rng, shape, scale=4.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# -- async-round generators (staleness weighting / virtual-clock sims) ------

def rand_data_weights(rng, n, lo=1.0, hi=500.0):
    """Per-client example counts: strictly positive floats."""
    return rng.uniform(lo, hi, n)


def rand_staleness(rng, n, hi=8):
    """Non-negative integer staleness values (version lag of an update)."""
    return rng.integers(0, hi + 1, n).astype(float)
