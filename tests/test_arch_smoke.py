"""Per-architecture smoke tests (deliverable (f)).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family config, run one forward and one FedGKD train step on CPU,
assert output shapes and no NaNs; plus decode-vs-forward consistency for a
representative of each attention family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.optim import sgd

ARCHS = configs.ALL_ARCHS


def _batch(cfg, b=2, s=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.enc_layers:
        batch["enc_embeddings"] = jax.random.normal(
            ks[2], (b, 8, cfg.d_model), cfg.adtype)
    elif cfg.frontend:
        batch["frontend_embeddings"] = jax.random.normal(
            ks[2], (b, cfg.frontend_seq or 16, cfg.d_model), cfg.adtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = configs.get_smoke_config(arch)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    kw = {}
    if cfg.enc_layers:
        kw["enc_out"] = transformer.encode(params, cfg, batch["enc_embeddings"])
    elif cfg.frontend:
        kw["prefix_embeddings"] = batch["frontend_embeddings"]
    logits, aux = transformer.forward(params, cfg, batch["tokens"], **kw)
    expect_s = batch["tokens"].shape[1] + (
        batch["frontend_embeddings"].shape[1]
        if (cfg.frontend and not cfg.enc_layers) else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_fedgkd_train_step(arch):
    """One FedGKD local step: loss finite, params change, KD term >= 0."""
    cfg = configs.get_smoke_config(arch)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    teacher = transformer.init(jax.random.PRNGKey(1), cfg)
    opt = sgd(momentum=0.9)
    step = jax.jit(steps_lib.make_train_step(cfg, opt, kd_mode="teacher",
                                             gamma=0.2, lr=0.05))
    batch = _batch(cfg)
    new_params, opt_state, metrics = step(params, teacher, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["kd"]) >= -1e-6
    # params must have moved
    delta = sum(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "mixtral-8x7b",
                                  "mamba2-2.7b", "deepseek-v3-671b",
                                  "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Greedy decode over the cache must reproduce teacher-forced logits.

    MoE configs get a lossless capacity factor (E/top_k) — with dropping,
    prefill (grouped dispatch) and decode (single token) legitimately differ.
    """
    cfg = configs.get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=cfg.moe._replace(
            capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_cache(cfg, 1, 16, jnp.float32)
    outs = []
    for i in range(8):
        lg, cache = transformer.decode_step(params, cfg, toks[:, i:i + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_swa_decode_ring_buffer():
    """Sliding-window decode (ring cache) matches windowed full attention."""
    cfg = configs.get_smoke_config("mixtral-8x7b")  # attn_window=8 in smoke
    assert cfg.attn_window == 8
    # lossless MoE capacity, as in test_decode_matches_forward: with capacity
    # dropping, prefill (grouped dispatch) and decode (single token)
    # legitimately differ — here we are testing the attention ring cache, so
    # the MoE layer must be drop-free or its noise masks the comparison.
    cfg = cfg.replace(moe=cfg.moe._replace(
        capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    full_logits, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_cache(cfg, 1, 64, jnp.float32)  # ring size = 8
    outs = []
    for i in range(12):
        lg, cache = transformer.decode_step(params, cfg, toks[:, i:i + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_larger_than_window():
    """An oversized ring (max_len > window) must still mask out-of-window
    slots: slot validity is position-derived, not 'every written slot'."""
    from repro.models import attention as A
    d_model, n_heads, n_kv, hd, window = 32, 4, 2, 8, 4
    params = A.gqa_init(jax.random.PRNGKey(0), d_model, n_heads, n_kv, hd)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 10, d_model))
    full = A.gqa_attention(params, x, n_heads=n_heads, n_kv_heads=n_kv,
                           head_dim=hd, positions=jnp.arange(10)[None, :],
                           window=window)
    for ring in (window, 6, 16):         # exact, oversized, >seq oversized
        cache = A.kv_cache_init(1, ring, n_kv, hd, jnp.float32)
        outs = []
        for i in range(10):
            y, cache = A.gqa_decode_step(
                params, x[:, i:i + 1], cache, n_heads=n_heads,
                n_kv_heads=n_kv, head_dim=hd, window=window)
            outs.append(y[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=2e-5, atol=2e-5, err_msg=f"ring={ring}")


def test_param_count_analytic_matches_actual():
    """config.param_count() (used for MODEL_FLOPS) vs real tree size."""
    for arch in ARCHS:
        cfg = configs.get_smoke_config(arch)
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        actual = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        # analytic count ignores a few small tensors (mtp head, norms detail);
        # must be within 15%
        assert abs(est - actual) / actual < 0.15, (arch, est, actual)
