"""Per-kernel allclose: flash attention vs jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops, ref
from proptest import sweep


def _run(b, sq, skv, hq, hkv, d, causal=True, window=None, dtype=jnp.float32,
         bq=32, bkv=32, tol=5e-5):
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + sq), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d)).astype(dtype)
    out = ops.flash_attention_gqa(q, k, v, causal=causal, window=window,
                                  block_q=bq, block_kv=bkv)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("sq,skv", [(32, 32), (64, 64), (100, 100), (96, 96)])
def test_causal_shapes(sq, skv):
    _run(2, sq, skv, 4, 2, 32)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1), (6, 3)])
def test_gqa_groups(hq, hkv):
    _run(1, 64, 64, hq, hkv, 32)


@pytest.mark.parametrize("window", [16, 32, 64])
def test_sliding_window(window):
    _run(1, 128, 128, 4, 2, 32, window=window)


@pytest.mark.parametrize("d", [32, 64, 128])
def test_head_dims(d):
    _run(1, 64, 64, 2, 2, d)


def test_bf16():
    _run(1, 64, 64, 4, 2, 64, dtype=jnp.bfloat16, tol=2e-2)


def test_noncausal_block_aligned():
    _run(1, 64, 64, 4, 4, 32, causal=False)


def test_gradients_flow():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))

    def f(q, k, v):
        return jnp.sum(ops.flash_attention_gqa(q, k, v, block_q=16, block_kv=16))

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    rq, rk, rv = jax.grad(
        lambda q, k, v: jnp.sum(ref.attention_ref(q, k, v)),
        argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(rq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(rk), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv), atol=1e-5)


@sweep(n=10)
def test_property_random_configs(rng):
    b = int(rng.integers(1, 3))
    sq = int(rng.integers(1, 5)) * 32
    hkv = int(rng.choice([1, 2, 4]))
    hq = hkv * int(rng.choice([1, 2, 4]))
    d = int(rng.choice([16, 32, 64]))
    window = int(rng.choice([0, 16, 48])) or None
    _run(b, sq, sq, hq, hkv, d, window=window)


@sweep(n=6)
def test_property_rows_are_convex_combinations(rng):
    """Each output row must lie in the convex hull of V rows: here we check
    max |out| <= max |v| (softmax weights sum to 1)."""
    sq = int(rng.integers(1, 4)) * 32
    ks = jax.random.split(jax.random.PRNGKey(int(rng.integers(1 << 30))), 3)
    q = jax.random.normal(ks[0], (1, sq, 2, 32))
    k = jax.random.normal(ks[1], (1, sq, 2, 32))
    v = jax.random.normal(ks[2], (1, sq, 2, 32))
    out = ops.flash_attention_gqa(q, k, v, block_q=32, block_kv=32)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-5
