"""Unit tests for the FL core: aggregation, buffers, KD losses, algorithms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms, distillation as D
from repro.core.server import ModelBuffer, weighted_average
from proptest import sweep


# --- server ----------------------------------------------------------------

def test_weighted_average_exact():
    a = {"w": jnp.ones((3,)), "b": jnp.zeros((2,))}
    b = {"w": 3 * jnp.ones((3,)), "b": 2 * jnp.ones((2,))}
    out = weighted_average([a, b], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)
    np.testing.assert_allclose(np.asarray(out["b"]), 1.5)


@sweep(n=10)
def test_property_average_idempotent(rng):
    """Averaging K copies of the same params returns those params."""
    p = {"w": jnp.asarray(rng.standard_normal((4, 5)), jnp.float32)}
    k = int(rng.integers(1, 6))
    w = rng.uniform(0.1, 5.0, size=k).tolist()
    out = weighted_average([p] * k, w)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(p["w"]),
                               atol=1e-6)


def test_model_buffer_fifo_and_fused():
    buf = ModelBuffer(3)
    for i in range(5):
        buf.push({"w": jnp.full((2,), float(i))})
    assert len(buf) == 3
    # newest first: 4, 3, 2
    vals = [float(m["w"][0]) for m in buf.models]
    assert vals == [4.0, 3.0, 2.0]
    np.testing.assert_allclose(np.asarray(buf.fused()["w"]), 3.0)


# --- distillation losses -----------------------------------------------------

def test_kl_zero_iff_equal():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((8, 10)),
                         jnp.float32)
    assert float(jnp.max(D.kl_divergence(logits, logits))) < 1e-6
    other = logits + 1e-1 * jnp.arange(10)[None, :]
    assert float(jnp.min(D.kl_divergence(logits, other))) > 0


def test_kd_loss_scaling():
    rng = np.random.default_rng(1)
    t = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    l1 = D.kd_loss_kl(t, s, gamma=0.2)
    l2 = D.kd_loss_kl(t, s, gamma=0.4)
    np.testing.assert_allclose(float(l2), 2 * float(l1), rtol=1e-6)


def test_vote_coefficients_sum_and_order():
    gammas = D.vote_coefficients([0.1, 0.5, 2.0], lam=0.1)
    # Σ γ_m/2 = λ
    np.testing.assert_allclose(sum(gammas) / 2, 0.1, rtol=1e-5)
    # lower validation loss ⇒ larger coefficient
    assert gammas[0] > gammas[1] > gammas[2]


def test_cross_entropy_ignore_index():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 2.0]], jnp.float32)
    labels = jnp.asarray([0, -1])
    ce = D.cross_entropy(logits, labels)
    want = -jax.nn.log_softmax(logits[0])[0]
    np.testing.assert_allclose(float(ce), float(want), rtol=1e-6)


def test_ensemble_average_is_mean():
    ms = [{"w": jnp.full((2,), float(i))} for i in range(4)]
    out = D.ensemble_average(ms)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.5)


# --- algorithm registry / semantics ------------------------------------------

def test_registry_complete():
    names = algorithms.available()
    for n in ["fedavg", "fedprox", "fedgkd", "fedgkd+", "fedgkd-vote",
              "moon", "feddistill+", "fedgen"]:
        assert n in names


def test_comm_multipliers_match_paper():
    """FedGKD: 2× when M>1, 1× when M=1; VOTE: M×; others 1×."""
    assert algorithms.make("fedgkd", buffer_m=1).comm_multiplier == 1.0
    assert algorithms.make("fedgkd", buffer_m=5).comm_multiplier == 2.0
    assert algorithms.make("fedgkd-vote", buffer_m=5).comm_multiplier == 5.0
    assert algorithms.make("fedavg").comm_multiplier == 1.0


def test_fedgkd_loss_reduces_to_fedavg_at_gamma0():
    from repro.configs.paper import CIFAR10, scaled
    from repro.core.modelzoo import make_model
    task = scaled(CIFAR10, 0.001)
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.ones((4, 32, 32, 3))
    y = jnp.asarray([0, 1, 2, 3])
    gkd = algorithms.make("fedgkd", gamma=0.0, buffer_m=1)
    server = gkd.init_server(params, model, task.num_classes)
    payload = gkd.round_payload(server, jax.random.PRNGKey(0))
    l_gkd, _ = gkd.loss_fn(model)(params, payload, (), x, y)
    avg = algorithms.make("fedavg")
    l_avg, _ = avg.loss_fn(model)(params, (), (), x, y)
    np.testing.assert_allclose(float(l_gkd), float(l_avg), rtol=1e-6)


def test_fedprox_penalizes_distance():
    from repro.configs.paper import CIFAR10, scaled
    from repro.core.modelzoo import make_model
    task = scaled(CIFAR10, 0.001)
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    far = jax.tree_util.tree_map(lambda p: p + 1.0, params)
    x = jnp.ones((2, 32, 32, 3))
    y = jnp.asarray([0, 1])
    prox = algorithms.make("fedprox", mu=0.1)
    payload = {"anchor": params}
    l_at, _ = prox.loss_fn(model)(params, payload, (), x, y)
    l_far, _ = prox.loss_fn(model)(far, payload, (), x, y)
    # the proximal term alone contributes mu/2 * n_params at distance 1
    assert float(l_far) > float(l_at)


def test_scaffold_participation_fraction():
    """SCAFFOLD's global control variate moves by |S|/K · mean(Δc_k): half
    participation must move c exactly half as far as full participation."""
    sc = algorithms.make("scaffold", lr=0.1, local_steps_hint=10)
    params = {"w": jnp.zeros((3,))}
    server = sc.init_server(params, model=None, num_classes=2)
    uploads = [{"params": {"w": jnp.full((3,), -1.0)}},
               {"params": {"w": jnp.full((3,), -3.0)}}]
    weights = [1.0, 1.0]
    half = sc.server_update(dict(server), uploads, weights, None,
                            n_clients=4)   # |S|=2 of K=4
    full = sc.server_update(dict(server), uploads, weights, None,
                            n_clients=2)   # |S|=K=2
    np.testing.assert_allclose(np.asarray(half["c"]["w"]),
                               np.asarray(full["c"]["w"]) / 2, rtol=1e-6)
    # legacy call without n_clients keeps the old full-participation reading
    legacy = sc.server_update(dict(server), uploads, weights, None)
    np.testing.assert_allclose(np.asarray(legacy["c"]["w"]),
                               np.asarray(full["c"]["w"]), rtol=1e-6)


def test_fedgen_init_server_requires_probe():
    gen = algorithms.make("fedgen")
    with pytest.raises(TypeError, match="init_server_with_probe"):
        gen.init_server({}, model=None, num_classes=3)


def test_fedgkd_vote_payload_padding():
    from repro.configs.paper import CIFAR10, scaled
    from repro.core.modelzoo import make_model
    task = scaled(CIFAR10, 0.001)
    model = make_model(task)
    params = model.init(jax.random.PRNGKey(0))
    vote = algorithms.make("fedgkd-vote", buffer_m=4)
    server = vote.init_server(params, model, task.num_classes)
    payload = vote.round_payload(server, jax.random.PRNGKey(0))
    # only 1 model buffered: padded entries carry γ=0
    g = np.asarray(payload["gammas"])
    assert g.shape == (4,)
    assert g[1:].sum() == 0.0 and g[0] > 0
    lead = jax.tree_util.tree_leaves(payload["teachers"])[0]
    assert lead.shape[0] == 4
